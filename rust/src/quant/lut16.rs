//! Blockwise LUT16 ADC scan: SIMD-friendly posting layout + quantized
//! lookup kernel.
//!
//! The row-major packed codes in [`crate::index::PostingList`] force the
//! scalar ADC scan ([`super::ProductQuantizer::adc_score`]) through two
//! dependent loads per byte — one for the code, one for the f32 LUT entry —
//! which caps throughput far below memory bandwidth. Production PQ systems
//! (ScaNN's LUT16 being the canonical example) fix this with two changes
//! implemented here:
//!
//! 1. **Blocked transposed codes** ([`BlockedCodes`]): posting-list codes
//!    are regrouped into blocks of [`BLOCK`] = 32 candidates. Within a
//!    block, each subspace owns one 16-byte *nibble plane*: byte `j` holds
//!    candidate `j`'s 4-bit code in its low nibble and candidate `16+j`'s
//!    in its high nibble. A 16-byte load therefore fetches one subspace of
//!    all 32 candidates.
//! 2. **Quantized LUT** ([`QueryLut`]): the per-query f32 LUT is affinely
//!    quantized to u8 (`value ≈ bias_sub + scale · u8`, one shared `scale`,
//!    per-subspace biases folded into one `bias`). A 16-entry u8 LUT fits
//!    a SIMD register, so `pshufb` performs 16 table lookups per
//!    instruction, and per-candidate sums accumulate in u16 lanes.
//!
//! Kernels: an AVX-512 VBMI path (four subspaces per iteration via
//! `vpermb`, compiled only when the toolchain has stable AVX-512
//! intrinsics — the `soar_avx512` cfg emitted by `build.rs`), an AVX2
//! path (two subspaces per iteration), an SSSE3 path, and a portable
//! scalar-blocked path. All produce **bit-identical** scores: they
//! compute the same exact integer sums (u16 accumulation cannot overflow
//! — [`QueryLut`] refuses to quantize when `m > 257`) and share one float
//! reconstruction expression. Dispatch is by runtime feature detection,
//! cached process-wide. The block loop software-prefetches the next
//! block's nibble planes so the scan streams at memory bandwidth instead
//! of stalling on demand misses.

use crate::quant::pq::PQ_CENTERS;

/// Candidates per block (two 16-lane SIMD halves).
pub const BLOCK: usize = 32;

/// Bytes per nibble plane (= [`PQ_CENTERS`]).
const PLANE: usize = PQ_CENTERS;

// ---------------------------------------------------------------------
// Per-query LUT with u8 quantization
// ---------------------------------------------------------------------

/// Per-query lookup table: the exact f32 LUT plus its u8 quantization.
///
/// Built by [`super::ProductQuantizer::build_query_lut`]. All buffers are
/// reused across queries — steady-state rebuilds never touch the
/// allocator (the vectors are sized once, at scratch construction).
#[derive(Clone, Debug, Default)]
pub struct QueryLut {
    /// Exact LUT, `m * 16` entries: `f32_lut[sub * 16 + c] = ⟨q_sub, cb[c]⟩`.
    pub f32_lut: Vec<f32>,
    /// Quantized planes, `m * 16` bytes; plane `sub` is bytes
    /// `sub*16 .. sub*16+16`.
    pub u8_lut: Vec<u8>,
    /// Shared dequantization step: `value ≈ bias + scale · Σ u8`.
    pub scale: f32,
    /// Sum of per-subspace minima.
    pub bias: f32,
    /// False when quantization is unavailable (u16 accumulators would
    /// overflow at `m > 257`, or the LUT is non-finite); scoring then falls
    /// back to the exact f32 path.
    pub quantized: bool,
}

impl QueryLut {
    pub fn new() -> QueryLut {
        QueryLut::default()
    }

    /// A LUT with buffers pre-sized for `m` subspaces.
    pub fn sized(m: usize) -> QueryLut {
        QueryLut {
            f32_lut: vec![0.0; m * PQ_CENTERS],
            u8_lut: vec![0; m * PQ_CENTERS],
            scale: 0.0,
            bias: 0.0,
            quantized: false,
        }
    }
}

// ---------------------------------------------------------------------
// Blocked code layout
// ---------------------------------------------------------------------

/// Posting-list PQ codes transposed into SIMD-friendly 32-candidate
/// blocks of 16-byte nibble planes (one plane per subspace; ragged tail
/// zero-padded). Derived from the row-major codes at build/seal/load time
/// and never serialized.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct BlockedCodes {
    m: usize,
    len: usize,
    /// `num_blocks * m * 16` bytes.
    data: Vec<u8>,
}

impl BlockedCodes {
    /// Transpose `len` row-major packed codes (`code_bytes` each) into the
    /// blocked layout for `m` subspaces.
    pub fn from_codes(codes: &[u8], len: usize, code_bytes: usize, m: usize) -> BlockedCodes {
        debug_assert_eq!(codes.len(), len * code_bytes);
        debug_assert!(len == 0 || m.div_ceil(2) == code_bytes);
        let num_blocks = len.div_ceil(BLOCK);
        let mut data = vec![0u8; num_blocks * m * PLANE];
        for i in 0..len {
            let row = &codes[i * code_bytes..(i + 1) * code_bytes];
            let base = (i / BLOCK) * m * PLANE + (i % BLOCK) % PLANE;
            let high_half = (i % BLOCK) >= PLANE;
            for sub in 0..m {
                let nib = if sub % 2 == 0 {
                    row[sub / 2] & 0x0f
                } else {
                    row[sub / 2] >> 4
                };
                data[base + sub * PLANE] |= if high_half { nib << 4 } else { nib };
            }
        }
        BlockedCodes { m, len, data }
    }

    /// Candidates stored (excluding padding).
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Subspace count the layout was built for.
    pub fn num_subspaces(&self) -> usize {
        self.m
    }

    pub fn num_blocks(&self) -> usize {
        self.len.div_ceil(BLOCK)
    }

    /// The `m * 16` plane bytes of block `b`.
    #[inline]
    pub fn block_planes(&self, b: usize) -> &[u8] {
        &self.data[b * self.m * PLANE..(b + 1) * self.m * PLANE]
    }

    /// Heap bytes.
    pub fn memory_bytes(&self) -> usize {
        self.data.len()
    }
}

// ---------------------------------------------------------------------
// Kernels
// ---------------------------------------------------------------------

/// Which accumulation kernel scores a block.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelKind {
    /// Scalar-blocked fallback (bit-identical to the SIMD paths).
    Portable,
    /// 128-bit `pshufb` path.
    Ssse3,
    /// 256-bit path, two subspaces per iteration.
    Avx2,
    /// 512-bit `vpermb` path, four subspaces per iteration. Present only
    /// when the toolchain can compile stable AVX-512 intrinsics (the
    /// `soar_avx512` cfg from `build.rs`); selected only when the CPU
    /// reports avx512f+avx512bw+avx512vbmi.
    #[cfg(soar_avx512)]
    Avx512,
}

impl KernelKind {
    pub fn name(self) -> &'static str {
        match self {
            KernelKind::Portable => "portable",
            KernelKind::Ssse3 => "ssse3",
            KernelKind::Avx2 => "avx2",
            #[cfg(soar_avx512)]
            KernelKind::Avx512 => "avx512",
        }
    }

    /// Can this CPU execute the kernel?
    pub fn supported(self) -> bool {
        match self {
            KernelKind::Portable => true,
            #[cfg(target_arch = "x86_64")]
            KernelKind::Ssse3 => std::arch::is_x86_feature_detected!("ssse3"),
            #[cfg(target_arch = "x86_64")]
            KernelKind::Avx2 => std::arch::is_x86_feature_detected!("avx2"),
            #[cfg(soar_avx512)]
            KernelKind::Avx512 => {
                std::arch::is_x86_feature_detected!("avx512f")
                    && std::arch::is_x86_feature_detected!("avx512bw")
                    && std::arch::is_x86_feature_detected!("avx512vbmi")
            }
            #[cfg(not(target_arch = "x86_64"))]
            _ => false,
        }
    }
}

/// Best kernel supported by this CPU (cached after the first call).
/// Under Miri the portable kernel is forced: runtime feature detection is
/// interpreter-dependent, and the scalar tier is the one Miri verifies.
pub fn detect_kernel() -> KernelKind {
    static CACHE: crate::util::sync::OnceLock<KernelKind> = crate::util::sync::OnceLock::new();
    *CACHE.get_or_init(|| {
        if cfg!(miri) {
            return KernelKind::Portable;
        }
        #[cfg(soar_avx512)]
        {
            if KernelKind::Avx512.supported() {
                return KernelKind::Avx512;
            }
        }
        #[cfg(target_arch = "x86_64")]
        {
            if std::arch::is_x86_feature_detected!("avx2") {
                return KernelKind::Avx2;
            }
            if std::arch::is_x86_feature_detected!("ssse3") {
                return KernelKind::Ssse3;
            }
        }
        KernelKind::Portable
    })
}

/// Every kernel runnable on this CPU (for parity tests and benches).
pub fn available_kernels() -> Vec<KernelKind> {
    let mut kinds = vec![KernelKind::Portable];
    if cfg!(miri) {
        // Intrinsic kernels cannot run interpreted; parity tests degrade
        // to portable-vs-portable (a no-op) instead of failing.
        return kinds;
    }
    #[cfg(target_arch = "x86_64")]
    {
        if std::arch::is_x86_feature_detected!("ssse3") {
            kinds.push(KernelKind::Ssse3);
        }
        if std::arch::is_x86_feature_detected!("avx2") {
            kinds.push(KernelKind::Avx2);
        }
    }
    #[cfg(soar_avx512)]
    {
        if KernelKind::Avx512.supported() {
            kinds.push(KernelKind::Avx512);
        }
    }
    kinds
}

/// Scalar-blocked accumulation: `acc[j] = Σ_sub lut[sub][code(j, sub)]`.
fn accumulate_block_portable(planes: &[u8], lut: &[u8], m: usize, acc: &mut [u16; BLOCK]) {
    acc.fill(0);
    for sub in 0..m {
        let plane = &planes[sub * PLANE..(sub + 1) * PLANE];
        let table = &lut[sub * PLANE..(sub + 1) * PLANE];
        for j in 0..PLANE {
            let b = plane[j];
            acc[j] += table[(b & 0x0f) as usize] as u16;
            acc[j + PLANE] += table[(b >> 4) as usize] as u16;
        }
    }
}

/// Two-query scalar-blocked accumulation: each nibble is looked up in both
/// queries' tables while the plane byte is hot. The sums are the same
/// exact u16 sums as two [`accumulate_block_portable`] calls, so the
/// fusion cannot change a single bit of either query's result.
fn accumulate_block2_portable(
    planes: &[u8],
    lut_a: &[u8],
    lut_b: &[u8],
    m: usize,
    acc_a: &mut [u16; BLOCK],
    acc_b: &mut [u16; BLOCK],
) {
    acc_a.fill(0);
    acc_b.fill(0);
    for sub in 0..m {
        let plane = &planes[sub * PLANE..(sub + 1) * PLANE];
        let ta = &lut_a[sub * PLANE..(sub + 1) * PLANE];
        let tb = &lut_b[sub * PLANE..(sub + 1) * PLANE];
        for j in 0..PLANE {
            let b = plane[j];
            let lo = (b & 0x0f) as usize;
            let hi = (b >> 4) as usize;
            acc_a[j] += ta[lo] as u16;
            acc_a[j + PLANE] += ta[hi] as u16;
            acc_b[j] += tb[lo] as u16;
            acc_b[j + PLANE] += tb[hi] as u16;
        }
    }
}

/// # Safety
/// Requires SSSE3; `planes` and `lut` must hold at least `m * 16` bytes.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "ssse3")]
unsafe fn accumulate_block_ssse3(planes: &[u8], lut: &[u8], m: usize, acc: &mut [u16; BLOCK]) {
    use core::arch::x86_64::*;
    let zero = _mm_setzero_si128();
    let low_mask = _mm_set1_epi8(0x0f);
    let mut a0 = zero;
    let mut a1 = zero;
    let mut a2 = zero;
    let mut a3 = zero;
    for sub in 0..m {
        let table = _mm_loadu_si128(lut.as_ptr().add(sub * PLANE) as *const __m128i);
        let plane = _mm_loadu_si128(planes.as_ptr().add(sub * PLANE) as *const __m128i);
        let lo = _mm_and_si128(plane, low_mask);
        let hi = _mm_and_si128(_mm_srli_epi16(plane, 4), low_mask);
        let vlo = _mm_shuffle_epi8(table, lo);
        let vhi = _mm_shuffle_epi8(table, hi);
        a0 = _mm_add_epi16(a0, _mm_unpacklo_epi8(vlo, zero));
        a1 = _mm_add_epi16(a1, _mm_unpackhi_epi8(vlo, zero));
        a2 = _mm_add_epi16(a2, _mm_unpacklo_epi8(vhi, zero));
        a3 = _mm_add_epi16(a3, _mm_unpackhi_epi8(vhi, zero));
    }
    let out = acc.as_mut_ptr() as *mut __m128i;
    _mm_storeu_si128(out, a0);
    _mm_storeu_si128(out.add(1), a1);
    _mm_storeu_si128(out.add(2), a2);
    _mm_storeu_si128(out.add(3), a3);
}

/// Two-query SSSE3 accumulation: one plane load feeds `pshufb` lookups
/// into both queries' table registers. Each query's four accumulators see
/// exactly the sums [`accumulate_block_ssse3`] would produce.
///
/// # Safety
/// Requires SSSE3; `planes`, `lut_a`, and `lut_b` must hold at least
/// `m * 16` bytes.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "ssse3")]
unsafe fn accumulate_block2_ssse3(
    planes: &[u8],
    lut_a: &[u8],
    lut_b: &[u8],
    m: usize,
    acc_a: &mut [u16; BLOCK],
    acc_b: &mut [u16; BLOCK],
) {
    use core::arch::x86_64::*;
    let zero = _mm_setzero_si128();
    let low_mask = _mm_set1_epi8(0x0f);
    let mut a0 = zero;
    let mut a1 = zero;
    let mut a2 = zero;
    let mut a3 = zero;
    let mut b0 = zero;
    let mut b1 = zero;
    let mut b2 = zero;
    let mut b3 = zero;
    for sub in 0..m {
        let plane = _mm_loadu_si128(planes.as_ptr().add(sub * PLANE) as *const __m128i);
        let lo = _mm_and_si128(plane, low_mask);
        let hi = _mm_and_si128(_mm_srli_epi16(plane, 4), low_mask);
        let ta = _mm_loadu_si128(lut_a.as_ptr().add(sub * PLANE) as *const __m128i);
        let tb = _mm_loadu_si128(lut_b.as_ptr().add(sub * PLANE) as *const __m128i);
        let alo = _mm_shuffle_epi8(ta, lo);
        let ahi = _mm_shuffle_epi8(ta, hi);
        let blo = _mm_shuffle_epi8(tb, lo);
        let bhi = _mm_shuffle_epi8(tb, hi);
        a0 = _mm_add_epi16(a0, _mm_unpacklo_epi8(alo, zero));
        a1 = _mm_add_epi16(a1, _mm_unpackhi_epi8(alo, zero));
        a2 = _mm_add_epi16(a2, _mm_unpacklo_epi8(ahi, zero));
        a3 = _mm_add_epi16(a3, _mm_unpackhi_epi8(ahi, zero));
        b0 = _mm_add_epi16(b0, _mm_unpacklo_epi8(blo, zero));
        b1 = _mm_add_epi16(b1, _mm_unpackhi_epi8(blo, zero));
        b2 = _mm_add_epi16(b2, _mm_unpacklo_epi8(bhi, zero));
        b3 = _mm_add_epi16(b3, _mm_unpackhi_epi8(bhi, zero));
    }
    let out_a = acc_a.as_mut_ptr() as *mut __m128i;
    _mm_storeu_si128(out_a, a0);
    _mm_storeu_si128(out_a.add(1), a1);
    _mm_storeu_si128(out_a.add(2), a2);
    _mm_storeu_si128(out_a.add(3), a3);
    let out_b = acc_b.as_mut_ptr() as *mut __m128i;
    _mm_storeu_si128(out_b, b0);
    _mm_storeu_si128(out_b.add(1), b1);
    _mm_storeu_si128(out_b.add(2), b2);
    _mm_storeu_si128(out_b.add(3), b3);
}

/// # Safety
/// Requires AVX2; `planes` and `lut` must hold at least `m * 16` bytes.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn accumulate_block_avx2(planes: &[u8], lut: &[u8], m: usize, acc: &mut [u16; BLOCK]) {
    use core::arch::x86_64::*;
    let zero = _mm256_setzero_si256();
    let low_mask = _mm256_set1_epi8(0x0f);
    let mut a0 = zero;
    let mut a1 = zero;
    let mut a2 = zero;
    let mut a3 = zero;
    // Two subspaces per iteration: lane 0 accumulates the even subspace,
    // lane 1 the odd one; the lanes are folded together afterwards.
    for p in 0..m / 2 {
        let table = _mm256_loadu_si256(lut.as_ptr().add(p * 2 * PLANE) as *const __m256i);
        let plane = _mm256_loadu_si256(planes.as_ptr().add(p * 2 * PLANE) as *const __m256i);
        let lo = _mm256_and_si256(plane, low_mask);
        let hi = _mm256_and_si256(_mm256_srli_epi16(plane, 4), low_mask);
        let vlo = _mm256_shuffle_epi8(table, lo);
        let vhi = _mm256_shuffle_epi8(table, hi);
        a0 = _mm256_add_epi16(a0, _mm256_unpacklo_epi8(vlo, zero));
        a1 = _mm256_add_epi16(a1, _mm256_unpackhi_epi8(vlo, zero));
        a2 = _mm256_add_epi16(a2, _mm256_unpacklo_epi8(vhi, zero));
        a3 = _mm256_add_epi16(a3, _mm256_unpackhi_epi8(vhi, zero));
    }
    let mut s0 = _mm_add_epi16(_mm256_castsi256_si128(a0), _mm256_extracti128_si256(a0, 1));
    let mut s1 = _mm_add_epi16(_mm256_castsi256_si128(a1), _mm256_extracti128_si256(a1, 1));
    let mut s2 = _mm_add_epi16(_mm256_castsi256_si128(a2), _mm256_extracti128_si256(a2, 1));
    let mut s3 = _mm_add_epi16(_mm256_castsi256_si128(a3), _mm256_extracti128_si256(a3, 1));
    if m % 2 == 1 {
        let sub = m - 1;
        let zero128 = _mm_setzero_si128();
        let mask128 = _mm_set1_epi8(0x0f);
        let table = _mm_loadu_si128(lut.as_ptr().add(sub * PLANE) as *const __m128i);
        let plane = _mm_loadu_si128(planes.as_ptr().add(sub * PLANE) as *const __m128i);
        let lo = _mm_and_si128(plane, mask128);
        let hi = _mm_and_si128(_mm_srli_epi16(plane, 4), mask128);
        let vlo = _mm_shuffle_epi8(table, lo);
        let vhi = _mm_shuffle_epi8(table, hi);
        s0 = _mm_add_epi16(s0, _mm_unpacklo_epi8(vlo, zero128));
        s1 = _mm_add_epi16(s1, _mm_unpackhi_epi8(vlo, zero128));
        s2 = _mm_add_epi16(s2, _mm_unpacklo_epi8(vhi, zero128));
        s3 = _mm_add_epi16(s3, _mm_unpackhi_epi8(vhi, zero128));
    }
    let out = acc.as_mut_ptr() as *mut __m128i;
    _mm_storeu_si128(out, s0);
    _mm_storeu_si128(out.add(1), s1);
    _mm_storeu_si128(out.add(2), s2);
    _mm_storeu_si128(out.add(3), s3);
}

/// Two-query AVX2 accumulation: the shared plane/nibble extraction of
/// [`accumulate_block_avx2`] feeds `pshufb` lookups into both queries'
/// table registers (two subspaces per iteration, lanes folded after the
/// loop, 128-bit remainder for odd `m`). Exact u16 sums — bit-identical
/// per query to the single-query kernel.
///
/// # Safety
/// Requires AVX2; `planes`, `lut_a`, and `lut_b` must hold at least
/// `m * 16` bytes.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn accumulate_block2_avx2(
    planes: &[u8],
    lut_a: &[u8],
    lut_b: &[u8],
    m: usize,
    acc_a: &mut [u16; BLOCK],
    acc_b: &mut [u16; BLOCK],
) {
    use core::arch::x86_64::*;
    let zero = _mm256_setzero_si256();
    let low_mask = _mm256_set1_epi8(0x0f);
    let mut a0 = zero;
    let mut a1 = zero;
    let mut a2 = zero;
    let mut a3 = zero;
    let mut b0 = zero;
    let mut b1 = zero;
    let mut b2 = zero;
    let mut b3 = zero;
    for p in 0..m / 2 {
        let plane = _mm256_loadu_si256(planes.as_ptr().add(p * 2 * PLANE) as *const __m256i);
        let lo = _mm256_and_si256(plane, low_mask);
        let hi = _mm256_and_si256(_mm256_srli_epi16(plane, 4), low_mask);
        let ta = _mm256_loadu_si256(lut_a.as_ptr().add(p * 2 * PLANE) as *const __m256i);
        let tb = _mm256_loadu_si256(lut_b.as_ptr().add(p * 2 * PLANE) as *const __m256i);
        let alo = _mm256_shuffle_epi8(ta, lo);
        let ahi = _mm256_shuffle_epi8(ta, hi);
        let blo = _mm256_shuffle_epi8(tb, lo);
        let bhi = _mm256_shuffle_epi8(tb, hi);
        a0 = _mm256_add_epi16(a0, _mm256_unpacklo_epi8(alo, zero));
        a1 = _mm256_add_epi16(a1, _mm256_unpackhi_epi8(alo, zero));
        a2 = _mm256_add_epi16(a2, _mm256_unpacklo_epi8(ahi, zero));
        a3 = _mm256_add_epi16(a3, _mm256_unpackhi_epi8(ahi, zero));
        b0 = _mm256_add_epi16(b0, _mm256_unpacklo_epi8(blo, zero));
        b1 = _mm256_add_epi16(b1, _mm256_unpackhi_epi8(blo, zero));
        b2 = _mm256_add_epi16(b2, _mm256_unpacklo_epi8(bhi, zero));
        b3 = _mm256_add_epi16(b3, _mm256_unpackhi_epi8(bhi, zero));
    }
    let mut sa0 = _mm_add_epi16(_mm256_castsi256_si128(a0), _mm256_extracti128_si256(a0, 1));
    let mut sa1 = _mm_add_epi16(_mm256_castsi256_si128(a1), _mm256_extracti128_si256(a1, 1));
    let mut sa2 = _mm_add_epi16(_mm256_castsi256_si128(a2), _mm256_extracti128_si256(a2, 1));
    let mut sa3 = _mm_add_epi16(_mm256_castsi256_si128(a3), _mm256_extracti128_si256(a3, 1));
    let mut sb0 = _mm_add_epi16(_mm256_castsi256_si128(b0), _mm256_extracti128_si256(b0, 1));
    let mut sb1 = _mm_add_epi16(_mm256_castsi256_si128(b1), _mm256_extracti128_si256(b1, 1));
    let mut sb2 = _mm_add_epi16(_mm256_castsi256_si128(b2), _mm256_extracti128_si256(b2, 1));
    let mut sb3 = _mm_add_epi16(_mm256_castsi256_si128(b3), _mm256_extracti128_si256(b3, 1));
    if m % 2 == 1 {
        let sub = m - 1;
        let zero128 = _mm_setzero_si128();
        let mask128 = _mm_set1_epi8(0x0f);
        let plane = _mm_loadu_si128(planes.as_ptr().add(sub * PLANE) as *const __m128i);
        let lo = _mm_and_si128(plane, mask128);
        let hi = _mm_and_si128(_mm_srli_epi16(plane, 4), mask128);
        let ta = _mm_loadu_si128(lut_a.as_ptr().add(sub * PLANE) as *const __m128i);
        let tb = _mm_loadu_si128(lut_b.as_ptr().add(sub * PLANE) as *const __m128i);
        let alo = _mm_shuffle_epi8(ta, lo);
        let ahi = _mm_shuffle_epi8(ta, hi);
        let blo = _mm_shuffle_epi8(tb, lo);
        let bhi = _mm_shuffle_epi8(tb, hi);
        sa0 = _mm_add_epi16(sa0, _mm_unpacklo_epi8(alo, zero128));
        sa1 = _mm_add_epi16(sa1, _mm_unpackhi_epi8(alo, zero128));
        sa2 = _mm_add_epi16(sa2, _mm_unpacklo_epi8(ahi, zero128));
        sa3 = _mm_add_epi16(sa3, _mm_unpackhi_epi8(ahi, zero128));
        sb0 = _mm_add_epi16(sb0, _mm_unpacklo_epi8(blo, zero128));
        sb1 = _mm_add_epi16(sb1, _mm_unpackhi_epi8(blo, zero128));
        sb2 = _mm_add_epi16(sb2, _mm_unpacklo_epi8(bhi, zero128));
        sb3 = _mm_add_epi16(sb3, _mm_unpackhi_epi8(bhi, zero128));
    }
    let out_a = acc_a.as_mut_ptr() as *mut __m128i;
    _mm_storeu_si128(out_a, sa0);
    _mm_storeu_si128(out_a.add(1), sa1);
    _mm_storeu_si128(out_a.add(2), sa2);
    _mm_storeu_si128(out_a.add(3), sa3);
    let out_b = acc_b.as_mut_ptr() as *mut __m128i;
    _mm_storeu_si128(out_b, sb0);
    _mm_storeu_si128(out_b.add(1), sb1);
    _mm_storeu_si128(out_b.add(2), sb2);
    _mm_storeu_si128(out_b.add(3), sb3);
}

/// # Safety
/// Requires AVX-512 F+BW+VBMI; `planes` and `lut` must hold at least
/// `m * 16` bytes.
#[cfg(soar_avx512)]
#[target_feature(enable = "avx512f,avx512bw,avx512vbmi,ssse3")]
unsafe fn accumulate_block_avx512(planes: &[u8], lut: &[u8], m: usize, acc: &mut [u16; BLOCK]) {
    use core::arch::x86_64::*;
    let zero = _mm512_setzero_si512();
    let low_mask = _mm512_set1_epi8(0x0f);
    // `vpermb` indexes across the whole 64-byte table register, so each
    // 16-byte group of nibble indices is offset into its own subspace's
    // 16-byte table group: bytes 0-15 → +0, 16-31 → +16, 32-47 → +32,
    // 48-63 → +48.
    let group_offsets = _mm512_set_epi64(
        0x3030303030303030u64 as i64,
        0x3030303030303030u64 as i64,
        0x2020202020202020u64 as i64,
        0x2020202020202020u64 as i64,
        0x1010101010101010u64 as i64,
        0x1010101010101010u64 as i64,
        0,
        0,
    );
    let mut a0 = zero;
    let mut a1 = zero;
    let mut a2 = zero;
    let mut a3 = zero;
    // Four subspaces per iteration: 128-bit lane L of the 512-bit vectors
    // carries subspace 4p+L; the lanes are folded together afterwards.
    for p in 0..m / 4 {
        let table = _mm512_loadu_si512(lut.as_ptr().add(p * 4 * PLANE) as *const _);
        let plane = _mm512_loadu_si512(planes.as_ptr().add(p * 4 * PLANE) as *const _);
        let lo = _mm512_or_si512(_mm512_and_si512(plane, low_mask), group_offsets);
        let hi = _mm512_or_si512(
            _mm512_and_si512(_mm512_srli_epi16::<4>(plane), low_mask),
            group_offsets,
        );
        let vlo = _mm512_permutexvar_epi8(lo, table);
        let vhi = _mm512_permutexvar_epi8(hi, table);
        a0 = _mm512_add_epi16(a0, _mm512_unpacklo_epi8(vlo, zero));
        a1 = _mm512_add_epi16(a1, _mm512_unpackhi_epi8(vlo, zero));
        a2 = _mm512_add_epi16(a2, _mm512_unpacklo_epi8(vhi, zero));
        a3 = _mm512_add_epi16(a3, _mm512_unpackhi_epi8(vhi, zero));
    }
    // Fold the four 128-bit lanes of each accumulator (exact u16 sums, so
    // fold order cannot change the result).
    let mut s0 = _mm_add_epi16(
        _mm_add_epi16(
            _mm512_extracti32x4_epi32::<0>(a0),
            _mm512_extracti32x4_epi32::<1>(a0),
        ),
        _mm_add_epi16(
            _mm512_extracti32x4_epi32::<2>(a0),
            _mm512_extracti32x4_epi32::<3>(a0),
        ),
    );
    let mut s1 = _mm_add_epi16(
        _mm_add_epi16(
            _mm512_extracti32x4_epi32::<0>(a1),
            _mm512_extracti32x4_epi32::<1>(a1),
        ),
        _mm_add_epi16(
            _mm512_extracti32x4_epi32::<2>(a1),
            _mm512_extracti32x4_epi32::<3>(a1),
        ),
    );
    let mut s2 = _mm_add_epi16(
        _mm_add_epi16(
            _mm512_extracti32x4_epi32::<0>(a2),
            _mm512_extracti32x4_epi32::<1>(a2),
        ),
        _mm_add_epi16(
            _mm512_extracti32x4_epi32::<2>(a2),
            _mm512_extracti32x4_epi32::<3>(a2),
        ),
    );
    let mut s3 = _mm_add_epi16(
        _mm_add_epi16(
            _mm512_extracti32x4_epi32::<0>(a3),
            _mm512_extracti32x4_epi32::<1>(a3),
        ),
        _mm_add_epi16(
            _mm512_extracti32x4_epi32::<2>(a3),
            _mm512_extracti32x4_epi32::<3>(a3),
        ),
    );
    // SSE remainder for the last m % 4 subspaces (same shape as the SSSE3
    // kernel's body).
    let zero128 = _mm_setzero_si128();
    let mask128 = _mm_set1_epi8(0x0f);
    for sub in (m - m % 4)..m {
        let table = _mm_loadu_si128(lut.as_ptr().add(sub * PLANE) as *const __m128i);
        let plane = _mm_loadu_si128(planes.as_ptr().add(sub * PLANE) as *const __m128i);
        let lo = _mm_and_si128(plane, mask128);
        let hi = _mm_and_si128(_mm_srli_epi16(plane, 4), mask128);
        let vlo = _mm_shuffle_epi8(table, lo);
        let vhi = _mm_shuffle_epi8(table, hi);
        s0 = _mm_add_epi16(s0, _mm_unpacklo_epi8(vlo, zero128));
        s1 = _mm_add_epi16(s1, _mm_unpackhi_epi8(vlo, zero128));
        s2 = _mm_add_epi16(s2, _mm_unpacklo_epi8(vhi, zero128));
        s3 = _mm_add_epi16(s3, _mm_unpackhi_epi8(vhi, zero128));
    }
    let out = acc.as_mut_ptr() as *mut __m128i;
    _mm_storeu_si128(out, s0);
    _mm_storeu_si128(out.add(1), s1);
    _mm_storeu_si128(out.add(2), s2);
    _mm_storeu_si128(out.add(3), s3);
}

/// Two-query AVX-512 accumulation: the shared `vpermb` index vectors of
/// [`accumulate_block_avx512`] (four subspaces per iteration, group-offset
/// trick) gather from both queries' 64-byte table registers. Same lane
/// folds, same SSE remainder — exact u16 sums, bit-identical per query to
/// the single-query kernel.
///
/// # Safety
/// Requires AVX-512 F+BW+VBMI; `planes`, `lut_a`, and `lut_b` must hold
/// at least `m * 16` bytes.
#[cfg(soar_avx512)]
#[target_feature(enable = "avx512f,avx512bw,avx512vbmi,ssse3")]
unsafe fn accumulate_block2_avx512(
    planes: &[u8],
    lut_a: &[u8],
    lut_b: &[u8],
    m: usize,
    acc_a: &mut [u16; BLOCK],
    acc_b: &mut [u16; BLOCK],
) {
    use core::arch::x86_64::*;
    let zero = _mm512_setzero_si512();
    let low_mask = _mm512_set1_epi8(0x0f);
    let group_offsets = _mm512_set_epi64(
        0x3030303030303030u64 as i64,
        0x3030303030303030u64 as i64,
        0x2020202020202020u64 as i64,
        0x2020202020202020u64 as i64,
        0x1010101010101010u64 as i64,
        0x1010101010101010u64 as i64,
        0,
        0,
    );
    let mut a0 = zero;
    let mut a1 = zero;
    let mut a2 = zero;
    let mut a3 = zero;
    let mut b0 = zero;
    let mut b1 = zero;
    let mut b2 = zero;
    let mut b3 = zero;
    for p in 0..m / 4 {
        let plane = _mm512_loadu_si512(planes.as_ptr().add(p * 4 * PLANE) as *const _);
        let lo = _mm512_or_si512(_mm512_and_si512(plane, low_mask), group_offsets);
        let hi = _mm512_or_si512(
            _mm512_and_si512(_mm512_srli_epi16::<4>(plane), low_mask),
            group_offsets,
        );
        let ta = _mm512_loadu_si512(lut_a.as_ptr().add(p * 4 * PLANE) as *const _);
        let tb = _mm512_loadu_si512(lut_b.as_ptr().add(p * 4 * PLANE) as *const _);
        let alo = _mm512_permutexvar_epi8(lo, ta);
        let ahi = _mm512_permutexvar_epi8(hi, ta);
        let blo = _mm512_permutexvar_epi8(lo, tb);
        let bhi = _mm512_permutexvar_epi8(hi, tb);
        a0 = _mm512_add_epi16(a0, _mm512_unpacklo_epi8(alo, zero));
        a1 = _mm512_add_epi16(a1, _mm512_unpackhi_epi8(alo, zero));
        a2 = _mm512_add_epi16(a2, _mm512_unpacklo_epi8(ahi, zero));
        a3 = _mm512_add_epi16(a3, _mm512_unpackhi_epi8(ahi, zero));
        b0 = _mm512_add_epi16(b0, _mm512_unpacklo_epi8(blo, zero));
        b1 = _mm512_add_epi16(b1, _mm512_unpackhi_epi8(blo, zero));
        b2 = _mm512_add_epi16(b2, _mm512_unpacklo_epi8(bhi, zero));
        b3 = _mm512_add_epi16(b3, _mm512_unpackhi_epi8(bhi, zero));
    }
    // Fold the four 128-bit lanes of each accumulator (exact u16 sums, so
    // fold order cannot change the result).
    let mut sa0 = _mm_add_epi16(
        _mm_add_epi16(
            _mm512_extracti32x4_epi32::<0>(a0),
            _mm512_extracti32x4_epi32::<1>(a0),
        ),
        _mm_add_epi16(
            _mm512_extracti32x4_epi32::<2>(a0),
            _mm512_extracti32x4_epi32::<3>(a0),
        ),
    );
    let mut sa1 = _mm_add_epi16(
        _mm_add_epi16(
            _mm512_extracti32x4_epi32::<0>(a1),
            _mm512_extracti32x4_epi32::<1>(a1),
        ),
        _mm_add_epi16(
            _mm512_extracti32x4_epi32::<2>(a1),
            _mm512_extracti32x4_epi32::<3>(a1),
        ),
    );
    let mut sa2 = _mm_add_epi16(
        _mm_add_epi16(
            _mm512_extracti32x4_epi32::<0>(a2),
            _mm512_extracti32x4_epi32::<1>(a2),
        ),
        _mm_add_epi16(
            _mm512_extracti32x4_epi32::<2>(a2),
            _mm512_extracti32x4_epi32::<3>(a2),
        ),
    );
    let mut sa3 = _mm_add_epi16(
        _mm_add_epi16(
            _mm512_extracti32x4_epi32::<0>(a3),
            _mm512_extracti32x4_epi32::<1>(a3),
        ),
        _mm_add_epi16(
            _mm512_extracti32x4_epi32::<2>(a3),
            _mm512_extracti32x4_epi32::<3>(a3),
        ),
    );
    let mut sb0 = _mm_add_epi16(
        _mm_add_epi16(
            _mm512_extracti32x4_epi32::<0>(b0),
            _mm512_extracti32x4_epi32::<1>(b0),
        ),
        _mm_add_epi16(
            _mm512_extracti32x4_epi32::<2>(b0),
            _mm512_extracti32x4_epi32::<3>(b0),
        ),
    );
    let mut sb1 = _mm_add_epi16(
        _mm_add_epi16(
            _mm512_extracti32x4_epi32::<0>(b1),
            _mm512_extracti32x4_epi32::<1>(b1),
        ),
        _mm_add_epi16(
            _mm512_extracti32x4_epi32::<2>(b1),
            _mm512_extracti32x4_epi32::<3>(b1),
        ),
    );
    let mut sb2 = _mm_add_epi16(
        _mm_add_epi16(
            _mm512_extracti32x4_epi32::<0>(b2),
            _mm512_extracti32x4_epi32::<1>(b2),
        ),
        _mm_add_epi16(
            _mm512_extracti32x4_epi32::<2>(b2),
            _mm512_extracti32x4_epi32::<3>(b2),
        ),
    );
    let mut sb3 = _mm_add_epi16(
        _mm_add_epi16(
            _mm512_extracti32x4_epi32::<0>(b3),
            _mm512_extracti32x4_epi32::<1>(b3),
        ),
        _mm_add_epi16(
            _mm512_extracti32x4_epi32::<2>(b3),
            _mm512_extracti32x4_epi32::<3>(b3),
        ),
    );
    // SSE remainder for the last m % 4 subspaces, both tables per plane.
    let zero128 = _mm_setzero_si128();
    let mask128 = _mm_set1_epi8(0x0f);
    for sub in (m - m % 4)..m {
        let plane = _mm_loadu_si128(planes.as_ptr().add(sub * PLANE) as *const __m128i);
        let lo = _mm_and_si128(plane, mask128);
        let hi = _mm_and_si128(_mm_srli_epi16(plane, 4), mask128);
        let ta = _mm_loadu_si128(lut_a.as_ptr().add(sub * PLANE) as *const __m128i);
        let tb = _mm_loadu_si128(lut_b.as_ptr().add(sub * PLANE) as *const __m128i);
        let alo = _mm_shuffle_epi8(ta, lo);
        let ahi = _mm_shuffle_epi8(ta, hi);
        let blo = _mm_shuffle_epi8(tb, lo);
        let bhi = _mm_shuffle_epi8(tb, hi);
        sa0 = _mm_add_epi16(sa0, _mm_unpacklo_epi8(alo, zero128));
        sa1 = _mm_add_epi16(sa1, _mm_unpackhi_epi8(alo, zero128));
        sa2 = _mm_add_epi16(sa2, _mm_unpacklo_epi8(ahi, zero128));
        sa3 = _mm_add_epi16(sa3, _mm_unpackhi_epi8(ahi, zero128));
        sb0 = _mm_add_epi16(sb0, _mm_unpacklo_epi8(blo, zero128));
        sb1 = _mm_add_epi16(sb1, _mm_unpackhi_epi8(blo, zero128));
        sb2 = _mm_add_epi16(sb2, _mm_unpacklo_epi8(bhi, zero128));
        sb3 = _mm_add_epi16(sb3, _mm_unpackhi_epi8(bhi, zero128));
    }
    let out_a = acc_a.as_mut_ptr() as *mut __m128i;
    _mm_storeu_si128(out_a, sa0);
    _mm_storeu_si128(out_a.add(1), sa1);
    _mm_storeu_si128(out_a.add(2), sa2);
    _mm_storeu_si128(out_a.add(3), sa3);
    let out_b = acc_b.as_mut_ptr() as *mut __m128i;
    _mm_storeu_si128(out_b, sb0);
    _mm_storeu_si128(out_b.add(1), sb1);
    _mm_storeu_si128(out_b.add(2), sb2);
    _mm_storeu_si128(out_b.add(3), sb3);
}

#[inline]
fn accumulate_block(
    kind: KernelKind,
    planes: &[u8],
    lut: &[u8],
    m: usize,
    acc: &mut [u16; BLOCK],
) {
    debug_assert!(planes.len() >= m * PLANE && lut.len() >= m * PLANE);
    match kind {
        KernelKind::Portable => accumulate_block_portable(planes, lut, m, acc),
        #[cfg(target_arch = "x86_64")]
        // SAFETY: score_all_with asserts `kind.supported()` (runtime
        // feature detection) and the slice bounds before dispatching here.
        KernelKind::Ssse3 => unsafe { accumulate_block_ssse3(planes, lut, m, acc) },
        #[cfg(target_arch = "x86_64")]
        // SAFETY: as above — AVX2 support and slice bounds are asserted by
        // score_all_with before any dispatch reaches this arm.
        KernelKind::Avx2 => unsafe { accumulate_block_avx2(planes, lut, m, acc) },
        #[cfg(soar_avx512)]
        // SAFETY: as above — AVX-512 F+BW+VBMI support and slice bounds
        // are asserted by score_all_with before dispatch.
        KernelKind::Avx512 => unsafe { accumulate_block_avx512(planes, lut, m, acc) },
        #[cfg(not(target_arch = "x86_64"))]
        _ => accumulate_block_portable(planes, lut, m, acc),
    }
}

#[inline]
#[allow(clippy::too_many_arguments)]
fn accumulate_block2(
    kind: KernelKind,
    planes: &[u8],
    lut_a: &[u8],
    lut_b: &[u8],
    m: usize,
    acc_a: &mut [u16; BLOCK],
    acc_b: &mut [u16; BLOCK],
) {
    debug_assert!(
        planes.len() >= m * PLANE && lut_a.len() >= m * PLANE && lut_b.len() >= m * PLANE
    );
    match kind {
        KernelKind::Portable => accumulate_block2_portable(planes, lut_a, lut_b, m, acc_a, acc_b),
        #[cfg(target_arch = "x86_64")]
        // SAFETY: score_all_group_with asserts `kind.supported()` (runtime
        // feature detection) and every LUT's slice bounds before
        // dispatching here.
        KernelKind::Ssse3 => unsafe {
            accumulate_block2_ssse3(planes, lut_a, lut_b, m, acc_a, acc_b)
        },
        #[cfg(target_arch = "x86_64")]
        // SAFETY: as above — AVX2 support and slice bounds are asserted by
        // score_all_group_with before any dispatch reaches this arm.
        KernelKind::Avx2 => unsafe {
            accumulate_block2_avx2(planes, lut_a, lut_b, m, acc_a, acc_b)
        },
        #[cfg(soar_avx512)]
        // SAFETY: as above — AVX-512 F+BW+VBMI support and slice bounds
        // are asserted by score_all_group_with before dispatch.
        KernelKind::Avx512 => unsafe {
            accumulate_block2_avx512(planes, lut_a, lut_b, m, acc_a, acc_b)
        },
        #[cfg(not(target_arch = "x86_64"))]
        _ => accumulate_block2_portable(planes, lut_a, lut_b, m, acc_a, acc_b),
    }
}

// ---------------------------------------------------------------------
// Whole-list scoring
// ---------------------------------------------------------------------

/// Score every candidate of a blocked posting list against a quantized
/// LUT, writing `cscore + bias + scale · Σ u8` per candidate into `out`
/// (resized to `blocked.len()`; the Vec is an arena — steady-state calls
/// never reallocate). Uses the best kernel for this CPU.
pub fn score_all(blocked: &BlockedCodes, lut: &QueryLut, cscore: f32, out: &mut Vec<f32>) {
    score_all_with(detect_kernel(), blocked, lut, cscore, out);
}

/// [`score_all`] with an explicit kernel (parity tests and benches).
pub fn score_all_with(
    kind: KernelKind,
    blocked: &BlockedCodes,
    lut: &QueryLut,
    cscore: f32,
    out: &mut Vec<f32>,
) {
    assert!(lut.quantized, "score_all requires a quantized LUT");
    // Keep the unsafe SIMD entry points unreachable with an unsupported
    // kind — executing them on a CPU without the feature is UB.
    assert!(kind.supported(), "kernel {} unsupported on this CPU", kind.name());
    out.resize(blocked.len, 0.0);
    if blocked.len == 0 {
        return;
    }
    let m = blocked.m;
    assert!(lut.u8_lut.len() >= m * PLANE, "LUT/{m}-subspace mismatch");
    // The quantization guard in build_query_lut keeps m ≤ 257; enforce it
    // here too so hand-built LUTs cannot overflow the u16 accumulators.
    assert!(m * (u8::MAX as usize) <= u16::MAX as usize);
    // serve-path: no-panic begin (input contracts asserted above; the scan
    // below must not reach an unwrap/expect)
    let mut acc = [0u16; BLOCK];
    let num_blocks = blocked.num_blocks();
    for b in 0..num_blocks {
        // Software-prefetch the next block's nibble planes while this one
        // accumulates: the scan walks `data` strictly forward, so the
        // lines are guaranteed to be wanted, and hiding the miss keeps the
        // pshufb/vpermb pipe fed on lists that overflow L2.
        #[cfg(target_arch = "x86_64")]
        if b + 1 < num_blocks {
            let next = blocked.block_planes(b + 1);
            let mut off = 0;
            // Up to 4 cache lines — covers a whole block at m ≤ 16.
            while off < next.len() && off < 256 {
                // SAFETY: prefetch has no semantic effect; the address is
                // in bounds of `next`.
                unsafe {
                    core::arch::x86_64::_mm_prefetch(
                        next.as_ptr().add(off) as *const i8,
                        core::arch::x86_64::_MM_HINT_T0,
                    );
                }
                off += 64;
            }
        }
        accumulate_block(kind, blocked.block_planes(b), &lut.u8_lut, m, &mut acc);
        let base = b * BLOCK;
        let lanes = BLOCK.min(blocked.len - base);
        // One canonical reconstruction expression — every kernel (and the
        // scalar reference `adc_score_quantized`) must match it bit-for-bit.
        for j in 0..lanes {
            out[base + j] = cscore + (lut.bias + lut.scale * acc[j] as f32);
        }
    }
    // serve-path: no-panic end
}

/// Multi-query grouped scan: score every candidate of one blocked posting
/// list against several queries' quantized LUTs in a **single pass** over
/// the nibble planes. See [`score_all_group_with`].
pub fn score_all_group(
    blocked: &BlockedCodes,
    luts: &[QueryLut],
    lut_idx: &[u32],
    cscores: &[f32],
    out: &mut [f32],
) {
    score_all_group_with(detect_kernel(), blocked, luts, lut_idx, cscores, out);
}

/// [`score_all_group`] with an explicit kernel (parity tests and benches).
///
/// Group member `g` uses `luts[lut_idx[g]]` with per-query base score
/// `cscores[g]` and writes its scores to `out[g * blocked.len() ..]` —
/// `out` must be exactly `lut_idx.len() * blocked.len()` long. Blocks
/// iterate outermost and queries innermost, so each block's planes are
/// fetched from memory once and stay L1-resident while every query
/// consumes them; adjacent query pairs are additionally fused into the
/// two-table `accumulate_block2` kernels (one plane load feeding both
/// LUT registers). Every member's output is bit-identical to a
/// [`score_all`] call with the same LUT: the accumulators are the same
/// exact u16 sums and the reconstruction expression is shared.
pub fn score_all_group_with(
    kind: KernelKind,
    blocked: &BlockedCodes,
    luts: &[QueryLut],
    lut_idx: &[u32],
    cscores: &[f32],
    out: &mut [f32],
) {
    let n = lut_idx.len();
    assert_eq!(cscores.len(), n, "cscores/lut_idx length mismatch");
    assert_eq!(out.len(), n * blocked.len, "out/group shape mismatch");
    if n == 0 || blocked.len == 0 {
        return;
    }
    // Keep the unsafe SIMD entry points unreachable with an unsupported
    // kind — executing them on a CPU without the feature is UB.
    assert!(kind.supported(), "kernel {} unsupported on this CPU", kind.name());
    let m = blocked.m;
    for &li in lut_idx {
        let lut = &luts[li as usize];
        assert!(lut.quantized, "score_all_group requires quantized LUTs");
        assert!(lut.u8_lut.len() >= m * PLANE, "LUT/{m}-subspace mismatch");
    }
    // The quantization guard in build_query_lut keeps m ≤ 257; enforce it
    // here too so hand-built LUTs cannot overflow the u16 accumulators.
    assert!(m * (u8::MAX as usize) <= u16::MAX as usize);
    // serve-path: no-panic begin (input contracts asserted above; the scan
    // below must not reach an unwrap/expect)
    let mut acc_a = [0u16; BLOCK];
    let mut acc_b = [0u16; BLOCK];
    let len = blocked.len;
    let num_blocks = blocked.num_blocks();
    for b in 0..num_blocks {
        // Same forward-streaming prefetch as score_all — issued once per
        // block, not once per (block, query): the whole point of the
        // grouped scan is that queries after the first hit L1.
        #[cfg(target_arch = "x86_64")]
        if b + 1 < num_blocks {
            let next = blocked.block_planes(b + 1);
            let mut off = 0;
            while off < next.len() && off < 256 {
                // SAFETY: prefetch has no semantic effect; the address is
                // in bounds of `next`.
                unsafe {
                    core::arch::x86_64::_mm_prefetch(
                        next.as_ptr().add(off) as *const i8,
                        core::arch::x86_64::_MM_HINT_T0,
                    );
                }
                off += 64;
            }
        }
        let planes = blocked.block_planes(b);
        let base = b * BLOCK;
        let lanes = BLOCK.min(len - base);
        let mut g = 0;
        while g + 1 < n {
            let la = &luts[lut_idx[g] as usize];
            let lb = &luts[lut_idx[g + 1] as usize];
            accumulate_block2(kind, planes, &la.u8_lut, &lb.u8_lut, m, &mut acc_a, &mut acc_b);
            // The same canonical reconstruction expression as score_all.
            for j in 0..lanes {
                out[g * len + base + j] = cscores[g] + (la.bias + la.scale * acc_a[j] as f32);
                out[(g + 1) * len + base + j] =
                    cscores[g + 1] + (lb.bias + lb.scale * acc_b[j] as f32);
            }
            g += 2;
        }
        if g < n {
            let la = &luts[lut_idx[g] as usize];
            accumulate_block(kind, planes, &la.u8_lut, m, &mut acc_a);
            for j in 0..lanes {
                out[g * len + base + j] = cscores[g] + (la.bias + la.scale * acc_a[j] as f32);
            }
        }
    }
    // serve-path: no-panic end
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Rng;

    fn random_codes(rng: &mut Rng, len: usize, code_bytes: usize) -> Vec<u8> {
        (0..len * code_bytes)
            .map(|_| (rng.next_u32() & 0xff) as u8)
            .collect()
    }

    fn random_lut(rng: &mut Rng, m: usize) -> QueryLut {
        QueryLut {
            f32_lut: Vec::new(),
            u8_lut: (0..m * PLANE)
                .map(|_| (rng.next_u32() & 0xff) as u8)
                .collect(),
            scale: 0.01 + rng.next_f32() * 0.05,
            bias: rng.next_f32() - 0.5,
            quantized: true,
        }
    }

    fn nibble(codes: &[u8], code_bytes: usize, i: usize, sub: usize) -> u8 {
        let b = codes[i * code_bytes + sub / 2];
        if sub % 2 == 0 {
            b & 0x0f
        } else {
            b >> 4
        }
    }

    #[test]
    fn blocked_layout_round_trips_nibbles() {
        let mut rng = Rng::new(1);
        for &(m, len) in &[(1usize, 1usize), (3, 17), (8, 32), (5, 33), (32, 100)] {
            let cb = m.div_ceil(2);
            let codes = random_codes(&mut rng, len, cb);
            let blocked = BlockedCodes::from_codes(&codes, len, cb, m);
            assert_eq!(blocked.len(), len);
            assert_eq!(blocked.num_blocks(), len.div_ceil(BLOCK));
            for i in 0..len {
                let planes = blocked.block_planes(i / BLOCK);
                let lane = i % BLOCK;
                for sub in 0..m {
                    let byte = planes[sub * PLANE + lane % PLANE];
                    let got = if lane < PLANE { byte & 0x0f } else { byte >> 4 };
                    assert_eq!(got, nibble(&codes, cb, i, sub), "i={i} sub={sub}");
                }
            }
        }
    }

    #[test]
    fn kernels_agree_bitwise() {
        let mut rng = Rng::new(2);
        for &(m, len) in &[(1usize, 5usize), (7, 64), (16, 95), (33, 200)] {
            let cb = m.div_ceil(2);
            let codes = random_codes(&mut rng, len, cb);
            let lut = random_lut(&mut rng, m);
            let blocked = BlockedCodes::from_codes(&codes, len, cb, m);
            let mut reference = Vec::new();
            score_all_with(KernelKind::Portable, &blocked, &lut, 0.25, &mut reference);
            // Scalar recomputation from the row-major codes.
            for i in 0..len {
                let mut total = 0u32;
                for sub in 0..m {
                    let nib = nibble(&codes, cb, i, sub) as usize;
                    total += lut.u8_lut[sub * PLANE + nib] as u32;
                }
                let want = 0.25 + (lut.bias + lut.scale * total as f32);
                assert_eq!(want.to_bits(), reference[i].to_bits(), "m={m} i={i}");
            }
            for kind in available_kernels() {
                let mut out = Vec::new();
                score_all_with(kind, &blocked, &lut, 0.25, &mut out);
                assert_eq!(out.len(), reference.len());
                for i in 0..len {
                    assert_eq!(
                        reference[i].to_bits(),
                        out[i].to_bits(),
                        "kernel {} m={m} i={i}",
                        kind.name()
                    );
                }
            }
        }
    }

    #[test]
    fn group_scan_matches_single_query_bitwise() {
        let mut rng = Rng::new(9);
        // Group sizes straddling the pair fusion (odd tail, singleton) and
        // shapes straddling the block size / odd-m remainders.
        for &(m, len) in &[(1usize, 5usize), (4, 31), (7, 64), (16, 95), (33, 200)] {
            let cb = m.div_ceil(2);
            let codes = random_codes(&mut rng, len, cb);
            let blocked = BlockedCodes::from_codes(&codes, len, cb, m);
            let luts: Vec<QueryLut> = (0..5).map(|_| random_lut(&mut rng, m)).collect();
            let cscores = [0.5f32, -1.25, 0.0, 2.0, 0.75];
            for group in [&[2u32][..], &[0, 3], &[4, 1, 2], &[0, 1, 2, 3, 4]] {
                for kind in available_kernels() {
                    let mut out = vec![0.0f32; group.len() * len];
                    let gs: Vec<f32> = group.iter().map(|&g| cscores[g as usize]).collect();
                    score_all_group_with(kind, &blocked, &luts, group, &gs, &mut out);
                    for (g, &li) in group.iter().enumerate() {
                        let mut want = Vec::new();
                        score_all_with(
                            kind,
                            &blocked,
                            &luts[li as usize],
                            cscores[li as usize],
                            &mut want,
                        );
                        for i in 0..len {
                            assert_eq!(
                                want[i].to_bits(),
                                out[g * len + i].to_bits(),
                                "kernel {} m={m} group={group:?} g={g} i={i}",
                                kind.name()
                            );
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn group_scan_empty_group_and_list() {
        let mut rng = Rng::new(10);
        let codes = random_codes(&mut rng, 10, 4);
        let blocked = BlockedCodes::from_codes(&codes, 10, 4, 8);
        let luts = [random_lut(&mut rng, 8)];
        // Empty group: no members, zero-length out.
        score_all_group(&blocked, &luts, &[], &[], &mut []);
        // Empty list: members but nothing to score.
        let empty = BlockedCodes::from_codes(&[], 0, 4, 8);
        score_all_group(&empty, &luts, &[0], &[1.0], &mut []);
    }

    #[test]
    #[should_panic(expected = "quantized")]
    fn group_scan_rejects_unquantized_member() {
        let blocked = BlockedCodes::from_codes(&[0u8; 4], 1, 4, 8);
        let luts = [QueryLut::sized(8)];
        let mut out = vec![0.0f32; 1];
        score_all_group(&blocked, &luts, &[0], &[0.0], &mut out);
    }

    #[test]
    fn empty_list_scores_nothing() {
        let blocked = BlockedCodes::from_codes(&[], 0, 4, 8);
        let mut lut = QueryLut::sized(8);
        lut.quantized = true;
        let mut out = vec![1.0f32; 3];
        score_all(&blocked, &lut, 0.0, &mut out);
        assert!(out.is_empty());
    }

    #[test]
    #[should_panic(expected = "quantized")]
    fn unquantized_lut_rejected() {
        let blocked = BlockedCodes::from_codes(&[0u8; 4], 1, 4, 8);
        let lut = QueryLut::sized(8);
        let mut out = Vec::new();
        score_all(&blocked, &lut, 0.0, &mut out);
    }
}
