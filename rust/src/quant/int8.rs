//! Int8 quantization — the "highest-bitrate representation" of the index.
//!
//! The paper's big-ann-benchmarks configuration stores datapoints as
//! INT8-quantized vectors (Appendix A.4.1) used for the final exact-ish
//! rerank stage; §3.5's memory analysis assumes `d` bytes per datapoint
//! for it. Per-dimension symmetric scaling: `x[j] ≈ code[j] * scale[j]`.

use crate::error::{Error, Result};
use crate::linalg::MatrixF32;

/// Per-dimension symmetric int8 quantizer.
#[derive(Clone, Debug, PartialEq)]
pub struct Int8Quantizer {
    /// `scale[j]` maps code −127..=127 back to floats for dimension j.
    pub scales: Vec<f32>,
}

impl Int8Quantizer {
    /// Fit scales from the per-dimension max |x| of `data`.
    pub fn train(data: &MatrixF32) -> Result<Int8Quantizer> {
        if data.rows() == 0 {
            return Err(Error::Config("cannot train int8 on empty data".into()));
        }
        let d = data.cols();
        let mut max_abs = vec![0.0f32; d];
        for row in data.iter_rows() {
            for j in 0..d {
                let a = row[j].abs();
                if a > max_abs[j] {
                    max_abs[j] = a;
                }
            }
        }
        let scales = max_abs
            .into_iter()
            .map(|m| if m > 0.0 { m / 127.0 } else { 1.0 })
            .collect();
        Ok(Int8Quantizer { scales })
    }

    pub fn dim(&self) -> usize {
        self.scales.len()
    }

    /// Quantize one vector.
    pub fn encode(&self, x: &[f32]) -> Vec<i8> {
        debug_assert_eq!(x.len(), self.scales.len());
        x.iter()
            .zip(&self.scales)
            .map(|(&v, &s)| (v / s).round().clamp(-127.0, 127.0) as i8)
            .collect()
    }

    /// Dequantize.
    pub fn decode(&self, code: &[i8]) -> Vec<f32> {
        code.iter()
            .zip(&self.scales)
            .map(|(&c, &s)| c as f32 * s)
            .collect()
    }

    /// ⟨q, decode(code)⟩ without materializing the decoded vector.
    /// `q_scaled` must be `q[j] * scale[j]` (precompute once per query via
    /// [`Int8Quantizer::scale_query`]).
    #[inline]
    pub fn dot_prescaled(q_scaled: &[f32], code: &[i8]) -> f32 {
        debug_assert_eq!(q_scaled.len(), code.len());
        let mut acc = 0.0f32;
        for j in 0..code.len() {
            acc += q_scaled[j] * code[j] as f32;
        }
        acc
    }

    /// Precompute the query-side scaling for [`Self::dot_prescaled`].
    pub fn scale_query(&self, q: &[f32]) -> Vec<f32> {
        q.iter().zip(&self.scales).map(|(&v, &s)| v * s).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{dot, Rng};

    fn random_data(n: usize, d: usize) -> MatrixF32 {
        let mut rng = Rng::new(11);
        let mut m = MatrixF32::zeros(n, d);
        for i in 0..n {
            rng.fill_gaussian(m.row_mut(i));
        }
        m
    }

    #[test]
    fn round_trip_error_small() {
        let data = random_data(200, 32);
        let q8 = Int8Quantizer::train(&data).unwrap();
        for i in 0..50 {
            let x = data.row(i);
            let back = q8.decode(&q8.encode(x));
            for j in 0..32 {
                assert!((x[j] - back[j]).abs() <= q8.scales[j] * 0.51 + 1e-6);
            }
        }
    }

    #[test]
    fn prescaled_dot_matches_decode_dot() {
        let data = random_data(100, 16);
        let q8 = Int8Quantizer::train(&data).unwrap();
        let mut rng = Rng::new(3);
        let mut q = vec![0.0f32; 16];
        rng.fill_gaussian(&mut q);
        let qs = q8.scale_query(&q);
        for i in 0..20 {
            let code = q8.encode(data.row(i));
            let fast = Int8Quantizer::dot_prescaled(&qs, &code);
            let slow = dot(&q, &q8.decode(&code));
            assert!((fast - slow).abs() < 1e-4);
        }
    }

    #[test]
    fn dot_error_bounded() {
        let data = random_data(300, 64);
        let q8 = Int8Quantizer::train(&data).unwrap();
        let mut rng = Rng::new(5);
        let mut q = vec![0.0f32; 64];
        rng.fill_gaussian(&mut q);
        let qs = q8.scale_query(&q);
        let mut rel_err_acc = 0.0f64;
        for i in 0..100 {
            let x = data.row(i);
            let exact = dot(&q, x);
            let approx = Int8Quantizer::dot_prescaled(&qs, &q8.encode(x));
            rel_err_acc += ((exact - approx).abs() / (exact.abs() + 1.0)) as f64;
        }
        assert!(rel_err_acc / 100.0 < 0.05, "mean rel err {}", rel_err_acc / 100.0);
    }

    #[test]
    fn constant_zero_dimension_ok() {
        let mut data = random_data(50, 4);
        for i in 0..50 {
            data.row_mut(i)[2] = 0.0;
        }
        let q8 = Int8Quantizer::train(&data).unwrap();
        assert_eq!(q8.scales[2], 1.0);
        let code = q8.encode(data.row(0));
        assert_eq!(code[2], 0);
    }

    #[test]
    fn empty_data_rejected() {
        assert!(Int8Quantizer::train(&MatrixF32::zeros(0, 4)).is_err());
    }
}
