//! The Appendix A.4 cost model: throughput-per-capex (Fig 12a) and
//! throughput-per-cloud-bill (Fig 12b) re-tabulation.
//!
//! The competing submissions' throughputs and hardware costs are taken
//! verbatim from the paper's tables (they came from the
//! big-ann-benchmarks leaderboard and vendor pricing); "Ours" plugs in a
//! *measured* QPS from this repo's serving stack, scaled by the paper's
//! machine cost. Because our testbed and corpus scale differ wildly from
//! the paper's, the absolute "Ours" row is labelled as such in the report
//! — the *computation* is the reproduction target here (see DESIGN.md §3).

/// Google Compute Engine on-demand monthly prices (USD) used by the paper
/// (us-central1, accessed 2023-03) — Appendix A.4.3.
pub mod gce {
    pub const VCPU_MONTH: f64 = 24.81;
    pub const GB_RAM_MONTH: f64 = 3.33;
    pub const GB_SSD_MONTH: f64 = 0.08;
    pub const A100_80GB_MONTH: f64 = 2868.90;
    pub const V100_16GB_MONTH: f64 = 1267.28;
}

/// One benchmark submission (paper-reported or ours).
#[derive(Clone, Debug)]
pub struct Submission {
    pub name: String,
    /// QPS at 90% recall@10 on MS-SPACEV.
    pub qps_spacev: f64,
    /// QPS at 90% recall@10 on MS-Turing.
    pub qps_turing: f64,
    /// Hardware purchase cost (USD); None if not priceable.
    pub capex_usd: Option<f64>,
    /// Monthly cloud bill (USD); None if hardware isn't cloud-available.
    pub cloud_usd_month: Option<f64>,
}

/// Monthly cloud bill for a CPU server shape.
pub fn cloud_cost_cpu(vcpus: f64, ram_gb: f64, ssd_gb: f64) -> f64 {
    vcpus * gce::VCPU_MONTH + ram_gb * gce::GB_RAM_MONTH + ssd_gb * gce::GB_SSD_MONTH
}

/// The paper's Appendix A.4 table, reproduced.
pub fn paper_submissions() -> Vec<Submission> {
    vec![
        Submission {
            name: "FAISS Baseline".into(),
            qps_spacev: 3265.0,
            qps_turing: 2845.0,
            capex_usd: Some(22_021.90),
            // 32 vCPU, 768 GB, 1× V100
            cloud_usd_month: Some(
                cloud_cost_cpu(32.0, 768.0, 0.0) + gce::V100_16GB_MONTH,
            ),
        },
        Submission {
            name: "DiskANN".into(),
            qps_spacev: 6503.0,
            qps_turing: 17201.0,
            capex_usd: Some(11_742.0),
            // 72 vCPU, 64 GB, 3276.8 GB SSD
            cloud_usd_month: Some(cloud_cost_cpu(72.0, 64.0, 3276.8)),
        },
        Submission {
            name: "Gemini".into(),
            qps_spacev: 16_422.0,
            qps_turing: 21_780.0,
            capex_usd: Some(55_726.66),
            cloud_usd_month: None, // proprietary hardware
        },
        Submission {
            name: "CuANNS-IVFPQ".into(),
            qps_spacev: 108_302.0,
            qps_turing: 109_745.0,
            capex_usd: Some(150_000.0),
            // 256 vCPU, 2048 GB, 1× A100 (only one GPU used)
            cloud_usd_month: Some(
                cloud_cost_cpu(256.0, 2048.0, 0.0) + gce::A100_80GB_MONTH,
            ),
        },
        Submission {
            name: "CuANNS-Multi".into(),
            qps_spacev: 839_749.0,
            qps_turing: 584_293.0,
            capex_usd: Some(150_000.0),
            cloud_usd_month: Some(
                cloud_cost_cpu(256.0, 2048.0, 0.0) + 8.0 * gce::A100_80GB_MONTH,
            ),
        },
        Submission {
            name: "OptANNe GraphANN".into(),
            qps_spacev: 157_828.0,
            qps_turing: 161_463.0,
            capex_usd: Some(14_664.20),
            cloud_usd_month: None, // Optane discontinued; not cloud-priceable
        },
    ]
}

/// The paper's "Ours" hardware shape: 32 vCPU / 150 GB, Supermicro capex.
pub fn ours_submission(qps_spacev: f64, qps_turing: f64) -> Submission {
    Submission {
        name: "Ours (SOAR)".into(),
        qps_spacev,
        qps_turing,
        capex_usd: Some(2740.60),
        cloud_usd_month: Some(cloud_cost_cpu(32.0, 150.0, 0.0)),
    }
}

/// The paper's reported "Ours" numbers for reference.
pub fn paper_ours() -> Submission {
    ours_submission(46_712.0, 32_608.0)
}

/// QPS-per-cost ratio rows (Fig 12a when `capex`, Fig 12b otherwise).
/// Returns `(name, spacev_ratio, turing_ratio)` skipping unpriceable rows.
pub fn ratio_table(subs: &[Submission], capex: bool) -> Vec<(String, f64, f64)> {
    subs.iter()
        .filter_map(|s| {
            let cost = if capex { s.capex_usd } else { s.cloud_usd_month }?;
            Some((
                s.name.clone(),
                s.qps_spacev / cost,
                s.qps_turing / cost,
            ))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cloud_costs_match_paper_appendix() {
        // Paper: DiskANN = $2261.18/month.
        let diskann = cloud_cost_cpu(72.0, 64.0, 3276.8);
        assert!((diskann - 2261.18).abs() < 1.0, "{diskann}");
        // Paper: FAISS = $4617.57/month (±$2: the paper's table carries
        // its own rounding; our exact sum is 4618.64).
        let faiss = cloud_cost_cpu(32.0, 768.0, 0.0) + gce::V100_16GB_MONTH;
        assert!((faiss - 4617.57).abs() < 2.0, "{faiss}");
        // Paper: CuANNS-IVFPQ = $16036.46 (±$5 paper-side rounding).
        let cuanns = cloud_cost_cpu(256.0, 2048.0, 0.0) + gce::A100_80GB_MONTH;
        assert!((cuanns - 16_036.46).abs() < 5.0, "{cuanns}");
        // Paper: CuANNS-Multi = $36118.76.
        let multi = cloud_cost_cpu(256.0, 2048.0, 0.0) + 8.0 * gce::A100_80GB_MONTH;
        assert!((multi - 36_118.76).abs() < 5.0, "{multi}");
        // Paper: Ours = $1293.09.
        let ours = cloud_cost_cpu(32.0, 150.0, 0.0);
        assert!((ours - 1293.09).abs() < 1.0, "{ours}");
    }

    #[test]
    fn cloud_ratio_table_matches_paper() {
        // Appendix A.4.3 table: throughput / monthly cloud cost.
        let mut subs = paper_submissions();
        subs.push(paper_ours());
        let rows = ratio_table(&subs, false);
        let find = |n: &str| rows.iter().find(|r| r.0.contains(n)).unwrap().clone();
        let faiss = find("FAISS");
        assert!((faiss.1 - 0.707).abs() < 0.01, "{}", faiss.1);
        assert!((faiss.2 - 0.616).abs() < 0.01, "{}", faiss.2);
        let diskann = find("DiskANN");
        assert!((diskann.1 - 2.876).abs() < 0.01);
        assert!((diskann.2 - 7.607).abs() < 0.01);
        let ours = find("Ours");
        assert!((ours.1 - 36.12).abs() < 0.1, "{}", ours.1);
        assert!((ours.2 - 25.22).abs() < 0.1, "{}", ours.2);
        // the paper's headline: Ours leads the cloud-cost ranking
        for r in &rows {
            if !r.0.contains("Ours") {
                assert!(ours.1 > r.1, "{} beats us on spacev", r.0);
                assert!(ours.2 > r.2, "{} beats us on turing", r.0);
            }
        }
    }

    #[test]
    fn capex_ratio_ranking_matches_fig12a() {
        let mut subs = paper_submissions();
        subs.push(paper_ours());
        let rows = ratio_table(&subs, true);
        // All 7 rows priceable by capex.
        assert_eq!(rows.len(), 7);
        let ours = rows.iter().find(|r| r.0.contains("Ours")).unwrap();
        // Paper: ours leads both capex rankings.
        for r in &rows {
            if !r.0.contains("Ours") {
                assert!(ours.1 > r.1, "{} beats us (spacev capex)", r.0);
                assert!(ours.2 > r.2, "{} beats us (turing capex)", r.0);
            }
        }
    }

    #[test]
    fn unpriceable_rows_skipped_in_cloud_table() {
        let rows = ratio_table(&paper_submissions(), false);
        assert!(rows.iter().all(|r| !r.0.contains("Gemini")));
        assert!(rows.iter().all(|r| !r.0.contains("OptANNe")));
    }
}
