//! Recall–throughput sweeps (the ann-benchmarks-style measurement behind
//! Fig 11 / Fig 12).
//!
//! A sweep runs the searcher over a grid of `(top_t, rerank_budget)`
//! operating points, measuring recall@k against exact ground truth and
//! single-thread query throughput, then reduces to the Pareto frontier.

use std::time::Instant;

use crate::config::SearchParams;
use crate::data::ground_truth::GroundTruth;
use crate::index::{Search, Searcher, SoarIndex};
use crate::linalg::MatrixF32;
use crate::runtime::Engine;

/// One measured operating point.
#[derive(Clone, Copy, Debug)]
pub struct RecallPoint {
    pub top_t: usize,
    pub rerank_budget: usize,
    pub recall: f64,
    /// Single-thread queries/second.
    pub qps: f64,
    /// Mean posting entries scanned per query.
    pub mean_points_scanned: f64,
}

/// Sweep the operating grid over a monolithic index. `k` is the recall@k
/// target.
pub fn recall_curve(
    index: &SoarIndex,
    engine: &Engine,
    queries: &MatrixF32,
    gt: &GroundTruth,
    k: usize,
    top_ts: &[usize],
    rerank_budgets: &[usize],
) -> Vec<RecallPoint> {
    recall_curve_with(&Searcher::new(index, engine), queries, gt, k, top_ts, rerank_budgets)
}

/// Sweep the operating grid over *any* [`Search`] implementation —
/// monolithic [`Searcher`], segmented `SnapshotSearcher`, or a sharded
/// `CollectionSearcher` — so eval drivers share one measurement loop.
pub fn recall_curve_with<S: Search>(
    searcher: &S,
    queries: &MatrixF32,
    gt: &GroundTruth,
    k: usize,
    top_ts: &[usize],
    rerank_budgets: &[usize],
) -> Vec<RecallPoint> {
    let mut scratch = searcher.new_scratch();
    let mut out = Vec::new();
    for &top_t in top_ts {
        for &rb in rerank_budgets {
            let params = SearchParams {
                k,
                top_t,
                rerank_budget: rb.max(k),
            };
            let mut results = Vec::with_capacity(queries.rows());
            let mut scanned = 0u64;
            let start = Instant::now();
            for qi in 0..queries.rows() {
                let (res, stats) = searcher.search(queries.row(qi), &params, &mut scratch);
                scanned += stats.points_scanned as u64;
                results.push(res.into_iter().map(|s| s.id).collect::<Vec<_>>());
            }
            let elapsed = start.elapsed().as_secs_f64();
            out.push(RecallPoint {
                top_t,
                rerank_budget: params.rerank_budget,
                recall: gt.mean_recall(&results),
                qps: queries.rows() as f64 / elapsed.max(1e-9),
                mean_points_scanned: scanned as f64 / queries.rows() as f64,
            });
        }
    }
    out
}

/// Reduce to the Pareto frontier (max QPS at each recall level),
/// sorted by ascending recall.
pub fn pareto_frontier(points: &[RecallPoint]) -> Vec<RecallPoint> {
    let mut sorted: Vec<RecallPoint> = points.to_vec();
    // Sort by descending recall, then descending qps; sweep keeping the
    // running max qps.
    sorted.sort_by(|a, b| {
        b.recall
            .partial_cmp(&a.recall)
            .unwrap()
            .then(b.qps.partial_cmp(&a.qps).unwrap())
    });
    let mut frontier: Vec<RecallPoint> = Vec::new();
    let mut best_qps = f64::NEG_INFINITY;
    for p in sorted {
        if p.qps > best_qps {
            best_qps = p.qps;
            frontier.push(p);
        }
    }
    frontier.reverse();
    frontier
}

/// Interpolate the QPS achievable at a given recall target from a
/// frontier (None if the target is unreachable).
pub fn qps_at_recall(frontier: &[RecallPoint], target: f64) -> Option<f64> {
    frontier
        .iter()
        .filter(|p| p.recall >= target)
        .map(|p| p.qps)
        .fold(None, |acc, q| Some(acc.map_or(q, |a: f64| a.max(q))))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{IndexConfig, SpillMode};
    use crate::data::ground_truth::ground_truth_mips;
    use crate::data::synthetic::SyntheticConfig;
    use crate::index::build_index;

    fn fixture() -> (crate::data::Dataset, SoarIndex, GroundTruth, Engine) {
        let ds = SyntheticConfig::glove_like(1500, 16, 16, 91).generate();
        let engine = Engine::cpu();
        let cfg = IndexConfig {
            num_partitions: 30,
            spill: SpillMode::Soar { lambda: 1.0 },
            ..Default::default()
        };
        let idx = build_index(&engine, &ds.data, &cfg).unwrap();
        let gt = ground_truth_mips(&ds.data, &ds.queries, 10);
        (ds, idx, gt, engine)
    }

    #[test]
    fn sweep_produces_monotone_scan_counts() {
        let (ds, idx, gt, engine) = fixture();
        let pts = recall_curve(&idx, &engine, &ds.queries, &gt, 10, &[1, 5, 30], &[100]);
        assert_eq!(pts.len(), 3);
        assert!(pts[0].mean_points_scanned < pts[2].mean_points_scanned);
        // probing everything should give high recall
        assert!(pts[2].recall > 0.8, "recall {}", pts[2].recall);
        for p in &pts {
            assert!(p.qps > 0.0);
        }
    }

    #[test]
    fn pareto_frontier_is_monotone() {
        let (ds, idx, gt, engine) = fixture();
        let pts = recall_curve(
            &idx,
            &engine,
            &ds.queries,
            &gt,
            10,
            &[1, 2, 5, 10, 30],
            &[50, 200],
        );
        let f = pareto_frontier(&pts);
        assert!(!f.is_empty());
        for w in f.windows(2) {
            assert!(w[1].recall >= w[0].recall);
            assert!(w[1].qps <= w[0].qps + 1e-9);
        }
    }

    #[test]
    fn recall_curve_with_spans_searcher_shapes() {
        use crate::config::CollectionConfig;
        use crate::index::{Collection, CollectionSearcher};
        use std::sync::Arc;
        let (ds, idx, gt, engine) = fixture();
        let direct = recall_curve(&idx, &engine, &ds.queries, &gt, 10, &[30], &[400]);
        // The same sweep through a 1-shard collection measures the same
        // recall and scan counts (QPS is wall-clock, so only recall and
        // points-scanned are comparable).
        let engine = Arc::new(engine);
        let c = Collection::build(
            engine.clone(),
            &ds.data,
            &crate::config::IndexConfig {
                num_partitions: 30,
                spill: crate::config::SpillMode::Soar { lambda: 1.0 },
                ..Default::default()
            },
            CollectionConfig::default(),
        )
        .unwrap();
        let snap = c.snapshot();
        let searcher = CollectionSearcher::new(&snap, &engine);
        let via_collection = recall_curve_with(&searcher, &ds.queries, &gt, 10, &[30], &[400]);
        assert_eq!(direct.len(), via_collection.len());
        assert!((direct[0].recall - via_collection[0].recall).abs() < 1e-9);
        assert_eq!(direct[0].mean_points_scanned, via_collection[0].mean_points_scanned);
    }

    #[test]
    fn qps_at_recall_interpolation() {
        let mk = |recall, qps| RecallPoint {
            top_t: 1,
            rerank_budget: 10,
            recall,
            qps,
            mean_points_scanned: 0.0,
        };
        let frontier = vec![mk(0.5, 1000.0), mk(0.9, 100.0)];
        assert_eq!(qps_at_recall(&frontier, 0.4), Some(1000.0));
        assert_eq!(qps_at_recall(&frontier, 0.8), Some(100.0));
        assert_eq!(qps_at_recall(&frontier, 0.95), None);
    }
}
