//! Terminal plotting + report emission for the experiment drivers.
//!
//! Each driver renders its figure as an ASCII chart (the repo has no
//! display dependencies) and dumps the raw series as JSON under
//! `reports/` so the numbers can be re-plotted elsewhere.

use std::path::Path;

use crate::error::Result;
use crate::util::json::Value;

/// An ASCII scatter/line chart over f64 points.
pub struct AsciiChart {
    pub title: String,
    pub x_label: String,
    pub y_label: String,
    pub width: usize,
    pub height: usize,
    pub log_x: bool,
    /// (legend glyph, points)
    pub series: Vec<(char, Vec<(f64, f64)>)>,
    pub legend: Vec<String>,
}

impl AsciiChart {
    pub fn new(title: &str, x_label: &str, y_label: &str) -> AsciiChart {
        AsciiChart {
            title: title.to_string(),
            x_label: x_label.to_string(),
            y_label: y_label.to_string(),
            width: 72,
            height: 20,
            log_x: false,
            series: Vec::new(),
            legend: Vec::new(),
        }
    }

    pub fn log_x(mut self) -> Self {
        self.log_x = true;
        self
    }

    pub fn series(mut self, glyph: char, label: &str, points: Vec<(f64, f64)>) -> Self {
        self.series.push((glyph, points));
        self.legend.push(format!("{glyph} = {label}"));
        self
    }

    fn tx(&self, x: f64) -> f64 {
        if self.log_x {
            x.max(1e-12).log10()
        } else {
            x
        }
    }

    /// Render to a string.
    pub fn render(&self) -> String {
        let mut pts: Vec<(f64, f64)> = Vec::new();
        for (_, s) in &self.series {
            for &(x, y) in s {
                pts.push((self.tx(x), y));
            }
        }
        if pts.is_empty() {
            return format!("{} (no data)\n", self.title);
        }
        let (mut x0, mut x1) = (f64::INFINITY, f64::NEG_INFINITY);
        let (mut y0, mut y1) = (f64::INFINITY, f64::NEG_INFINITY);
        for &(x, y) in &pts {
            x0 = x0.min(x);
            x1 = x1.max(x);
            y0 = y0.min(y);
            y1 = y1.max(y);
        }
        if (x1 - x0).abs() < 1e-12 {
            x1 = x0 + 1.0;
        }
        if (y1 - y0).abs() < 1e-12 {
            y1 = y0 + 1.0;
        }
        let mut grid = vec![vec![' '; self.width]; self.height];
        for (glyph, s) in &self.series {
            for &(x, y) in s {
                let gx = ((self.tx(x) - x0) / (x1 - x0) * (self.width - 1) as f64).round()
                    as usize;
                let gy = ((y - y0) / (y1 - y0) * (self.height - 1) as f64).round() as usize;
                let row = self.height - 1 - gy.min(self.height - 1);
                grid[row][gx.min(self.width - 1)] = *glyph;
            }
        }
        let mut out = String::new();
        out.push_str(&format!("  {}\n", self.title));
        out.push_str(&format!(
            "  {} (y: {:.4} .. {:.4})\n",
            self.y_label, y0, y1
        ));
        for row in &grid {
            out.push_str("  |");
            out.extend(row.iter());
            out.push('\n');
        }
        out.push_str("  +");
        out.push_str(&"-".repeat(self.width));
        out.push('\n');
        let x_desc = if self.log_x {
            format!(
                "  {} (x, log10: {:.2} .. {:.2})\n",
                self.x_label, x0, x1
            )
        } else {
            format!("  {} (x: {:.4} .. {:.4})\n", self.x_label, x0, x1)
        };
        out.push_str(&x_desc);
        for l in &self.legend {
            out.push_str(&format!("  {l}\n"));
        }
        out
    }
}

/// Fixed-width table rendering.
pub fn render_table(headers: &[&str], rows: &[Vec<String>]) -> String {
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let sep: String = widths
        .iter()
        .map(|w| format!("+{}", "-".repeat(w + 2)))
        .collect::<String>()
        + "+";
    let fmt_row = |cells: &[String]| -> String {
        let mut line = String::new();
        for (i, c) in cells.iter().enumerate() {
            line.push_str(&format!("| {:<w$} ", c, w = widths[i]));
        }
        line.push('|');
        line
    };
    let mut out = String::new();
    out.push_str(&sep);
    out.push('\n');
    out.push_str(&fmt_row(
        &headers.iter().map(|h| h.to_string()).collect::<Vec<_>>(),
    ));
    out.push('\n');
    out.push_str(&sep);
    out.push('\n');
    for row in rows {
        out.push_str(&fmt_row(row));
        out.push('\n');
    }
    out.push_str(&sep);
    out.push('\n');
    out
}

/// Write a JSON report under `dir` (created if needed).
pub fn write_report(dir: &Path, name: &str, value: &Value) -> Result<()> {
    std::fs::create_dir_all(dir)?;
    let path = dir.join(format!("{name}.json"));
    std::fs::write(&path, value.to_json_pretty())?;
    println!("  wrote {}", path.display());
    Ok(())
}

/// Series of (x, y) pairs as a JSON array.
pub fn series_json(points: &[(f64, f64)]) -> Value {
    Value::Arr(
        points
            .iter()
            .map(|&(x, y)| Value::Arr(vec![Value::num(x), Value::num(y)]))
            .collect(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::tempdir::TempDir;

    #[test]
    fn chart_renders_points() {
        let chart = AsciiChart::new("t", "x", "y")
            .series('o', "a", vec![(0.0, 0.0), (1.0, 1.0), (2.0, 4.0)])
            .series('x', "b", vec![(0.0, 4.0), (2.0, 0.0)]);
        let s = chart.render();
        assert!(s.contains('o'));
        assert!(s.contains('x'));
        assert!(s.contains("o = a"));
        assert!(s.lines().count() > 10);
    }

    #[test]
    fn chart_log_x_and_degenerate() {
        let c = AsciiChart::new("t", "x", "y").log_x().series(
            '*',
            "s",
            vec![(1.0, 1.0), (10.0, 1.0), (100.0, 1.0)],
        );
        let s = c.render();
        assert!(s.contains("log10"));
        let empty = AsciiChart::new("e", "x", "y").render();
        assert!(empty.contains("no data"));
    }

    #[test]
    fn table_renders_aligned() {
        let t = render_table(
            &["name", "value"],
            &[
                vec!["a".into(), "1".into()],
                vec!["long-name".into(), "1234567".into()],
            ],
        );
        assert!(t.contains("| a         |"));
        assert!(t.contains("| long-name |"));
    }

    #[test]
    fn report_round_trips() {
        let dir = TempDir::new().unwrap();
        let v = Value::obj(vec![("x", Value::num(1.5))]);
        write_report(dir.path(), "test", &v).unwrap();
        let text = std::fs::read_to_string(dir.join("test.json")).unwrap();
        assert_eq!(Value::parse(&text).unwrap(), v);
    }

    #[test]
    fn series_json_shape() {
        let v = series_json(&[(1.0, 2.0), (3.0, 4.0)]);
        let arr = v.as_arr().unwrap();
        assert_eq!(arr.len(), 2);
        assert_eq!(arr[1].as_arr().unwrap()[0].as_f64().unwrap(), 3.0);
    }
}
