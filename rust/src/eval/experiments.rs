//! Experiment drivers: one function per paper figure/table.
//!
//! Every driver prints its figure (ASCII chart / table) and writes the raw
//! series to `reports/<id>.json`. DESIGN.md §4 maps ids to paper
//! artifacts; EXPERIMENTS.md records the measured-vs-paper comparison.

use std::path::PathBuf;

use crate::config::{IndexConfig, SpillMode};
use crate::data::ground_truth::{ground_truth_mips, GroundTruth};
use crate::data::synthetic::SyntheticConfig;
use crate::data::Dataset;
use crate::error::Result;
use crate::eval::plot::{render_table, series_json, write_report, AsciiChart};
use crate::eval::recall::{pareto_frontier, qps_at_recall, recall_curve};
use crate::index::stats::{binned_means, collect_pair_stats, rank_binned_means};
use crate::index::{build_index, kmr::compute_kmr, soar, SoarIndex};
use crate::linalg::pearson;
use crate::runtime::Engine;
use crate::util::json::Value;

/// Shared experiment environment.
pub struct ExpConfig {
    /// Corpus size.
    pub n: usize,
    pub dim: usize,
    pub num_queries: usize,
    /// Neighbors per query in ground truth (paper uses k=100 for KMR,
    /// k=10 for end-to-end benchmarks).
    pub k: usize,
    pub seed: u64,
    /// SOAR λ for the default SOAR index.
    pub lambda: f32,
    /// Query perturbation scale. The paper's workloads (real query logs
    /// against web-scale corpora) are *hard*: many true neighbors live in
    /// poorly-ranked partitions. 0.25 gives trivially easy queries where
    /// spilling can't pay for its duplication; ≥0.5 reproduces the heavy
    /// tail of Fig 1.
    pub query_noise: f32,
    /// Within-cluster noise of the generator. Larger values put more
    /// points near partition boundaries → heavier tail of badly-ranked
    /// primary partitions (the regime where spilling pays; §5.3).
    pub data_noise: f32,
    /// Anisotropic VQ-training weight ratio η (0 disables). The paper
    /// trains every VQ stage with ScaNN's anisotropic loss (App. A.2).
    pub anisotropic_eta: f32,
    pub reports_dir: PathBuf,
    /// Shrink workloads for CI/smoke runs.
    pub quick: bool,
}

impl Default for ExpConfig {
    fn default() -> Self {
        ExpConfig {
            n: 20_000,
            dim: 64,
            num_queries: 200,
            k: 10,
            seed: 42,
            lambda: 1.0,
            query_noise: 0.6,
            data_noise: 0.55,
            anisotropic_eta: 0.0,
            reports_dir: PathBuf::from("reports"),
            quick: false,
        }
    }
}

impl ExpConfig {
    pub fn quick() -> Self {
        ExpConfig {
            n: 4000,
            num_queries: 50,
            quick: true,
            ..Default::default()
        }
    }

    fn dataset(&self) -> Dataset {
        let mut cfg =
            SyntheticConfig::glove_like(self.n, self.dim, self.num_queries, self.seed);
        cfg.query_noise = self.query_noise;
        cfg.noise = self.data_noise;
        cfg.generate()
    }

    fn index_config(&self, spill: SpillMode) -> IndexConfig {
        let mut cfg = IndexConfig::for_dataset(self.n, spill);
        cfg.kmeans.anisotropic_eta = self.anisotropic_eta;
        cfg
    }

    fn soar_mode(&self) -> SpillMode {
        SpillMode::Soar {
            lambda: self.lambda,
        }
    }
}

struct Env {
    ds: Dataset,
    gt: GroundTruth,
}

fn env(cfg: &ExpConfig, engine: &Engine, spill: SpillMode) -> Result<(Env, SoarIndex)> {
    let ds = cfg.dataset();
    let index = build_index(engine, &ds.data, &cfg.index_config(spill))?;
    let gt = ground_truth_mips(&ds.data, &ds.queries, cfg.k);
    Ok((Env { ds, gt }, index))
}

// ---------------------------------------------------------------------
// Fig 1: mean ⟨q,r⟩ vs RANK of the primary partition
// ---------------------------------------------------------------------

pub fn fig1(cfg: &ExpConfig, engine: &Engine) -> Result<()> {
    println!("== Fig 1: search difficulty vs quantized score error ==");
    let (e, index) = env(cfg, engine, SpillMode::None)?;
    let stats = collect_pair_stats(&index, &e.ds.data, &e.ds.queries, &e.gt);
    let ranks: Vec<u32> = stats.iter().map(|s| s.primary_rank).collect();
    let qr: Vec<f32> = stats.iter().map(|s| s.qr).collect();
    let bins = rank_binned_means(&ranks, &qr);
    let pts: Vec<(f64, f64)> = bins.iter().map(|&(r, m, _)| (r as f64, m)).collect();
    let chart = AsciiChart::new(
        "Fig 1: mean ⟨q,r⟩ vs RANK(q, C_π(x), C)",
        "RANK (log)",
        "mean ⟨q,r⟩",
    )
    .log_x()
    .series('o', "no-spill VQ index", pts.clone());
    println!("{}", chart.render());
    // Shape check: the highest-rank bucket must sit above the lowest.
    if let (Some(first), Some(last)) = (bins.first(), bins.last()) {
        let rising = last.1 > first.1;
        println!(
            "  shape: mean ⟨q,r⟩ rises from {:.4} (rank {}) to {:.4} (rank {}): {}",
            first.1,
            first.0,
            last.1,
            last.0,
            if rising { "OK (matches paper)" } else { "MISMATCH" }
        );
    }
    write_report(
        &cfg.reports_dir,
        "fig1",
        &Value::obj(vec![
            ("series", series_json(&pts)),
            ("pairs", Value::num(stats.len() as f64)),
        ]),
    )
}

// ---------------------------------------------------------------------
// Fig 2: cosθ vs ‖r‖ as predictors of ⟨q,r⟩
// ---------------------------------------------------------------------

pub fn fig2(cfg: &ExpConfig, engine: &Engine) -> Result<()> {
    println!("== Fig 2: ⟨q,r⟩ correlation with cosθ vs ‖r‖ ==");
    let (e, index) = env(cfg, engine, SpillMode::None)?;
    let stats = collect_pair_stats(&index, &e.ds.data, &e.ds.queries, &e.gt);
    let qr: Vec<f32> = stats.iter().map(|s| s.qr).collect();
    let cos: Vec<f32> = stats.iter().map(|s| s.cos_theta).collect();
    let rn: Vec<f32> = stats.iter().map(|s| s.r_norm).collect();
    let rho_cos = pearson(&cos, &qr);
    let rho_norm = pearson(&rn, &qr);
    let cos_bins = binned_means(&cos, &qr, 24);
    let norm_bins = binned_means(&rn, &qr, 24);
    let left = AsciiChart::new("Fig 2 (left): ⟨q,r⟩ vs cos θ", "cos θ", "mean ⟨q,r⟩")
        .series('o', "binned mean", cos_bins.iter().map(|&(x, y, _)| (x, y)).collect());
    let right = AsciiChart::new("Fig 2 (right): ⟨q,r⟩ vs ‖r‖", "‖r‖", "mean ⟨q,r⟩")
        .series('x', "binned mean", norm_bins.iter().map(|&(x, y, _)| (x, y)).collect());
    println!("{}", left.render());
    println!("{}", right.render());
    println!("  pearson(cosθ, ⟨q,r⟩)  = {rho_cos:.3}");
    println!("  pearson(‖r‖,  ⟨q,r⟩)  = {rho_norm:.3}");
    println!(
        "  shape: cosθ dominates: {}",
        if rho_cos > rho_norm.abs() {
            "OK (matches paper)"
        } else {
            "MISMATCH"
        }
    );
    write_report(
        &cfg.reports_dir,
        "fig2",
        &Value::obj(vec![
            ("rho_cos", Value::num(rho_cos as f64)),
            ("rho_norm", Value::num(rho_norm as f64)),
        ]),
    )
}

// ---------------------------------------------------------------------
// Fig 4: angle correlation under naive spilling / two-seed VQ
// Fig 7: same with SOAR
// ---------------------------------------------------------------------

fn angle_correlation(
    index: &SoarIndex,
    ds: &Dataset,
    gt: &GroundTruth,
) -> (f32, Vec<(f64, f64)>) {
    let stats = collect_pair_stats(index, &ds.data, &ds.queries, gt);
    let a: Vec<f32> = stats.iter().map(|s| s.cos_theta).collect();
    let b: Vec<f32> = stats.iter().map(|s| s.spill_cos).collect();
    let sample: Vec<(f64, f64)> = stats
        .iter()
        .take(600)
        .map(|s| (s.cos_theta as f64, s.spill_cos as f64))
        .collect();
    (pearson(&a, &b), sample)
}

pub fn fig4(cfg: &ExpConfig, engine: &Engine) -> Result<()> {
    println!("== Fig 4: naive spilled assignment angle correlation ==");
    // (a) top-2 Euclidean assignment within one index.
    let (e, idx_naive) = env(cfg, engine, SpillMode::Nearest)?;
    let (rho_naive, scatter_a) = angle_correlation(&idx_naive, &e.ds, &e.gt);

    // (b) two separately-seeded VQ indices: θ1/θ2 from each index's
    // *primary* residual.
    let mut cfg2 = cfg.index_config(SpillMode::None);
    cfg2.seed = cfg.seed.wrapping_add(1000);
    cfg2.kmeans.seed = cfg.seed.wrapping_add(1000);
    let idx_a = build_index(engine, &e.ds.data, &cfg.index_config(SpillMode::None))?;
    let idx_b = build_index(engine, &e.ds.data, &cfg2)?;
    let st_a = collect_pair_stats(&idx_a, &e.ds.data, &e.ds.queries, &e.gt);
    let st_b = collect_pair_stats(&idx_b, &e.ds.data, &e.ds.queries, &e.gt);
    let cos_a: Vec<f32> = st_a.iter().map(|s| s.cos_theta).collect();
    let cos_b: Vec<f32> = st_b.iter().map(|s| s.cos_theta).collect();
    let rho_two_seed = pearson(&cos_a, &cos_b);

    let chart = AsciiChart::new(
        "Fig 4a: cos θ vs cos θ' (naive top-2 spill)",
        "cos θ (primary)",
        "cos θ' (spill)",
    )
    .series('.', "pair", scatter_a);
    println!("{}", chart.render());
    println!("  4a pearson(cosθ, cosθ')      = {rho_naive:.3} (naive top-2)");
    println!("  4b pearson(cosθ₁, cosθ₂)     = {rho_two_seed:.3} (two seeds)");
    println!(
        "  shape: positive correlations: {}",
        if rho_naive > 0.0 && rho_two_seed > 0.0 {
            "OK (matches paper)"
        } else {
            "PARTIAL (small synthetic set)"
        }
    );
    write_report(
        &cfg.reports_dir,
        "fig4",
        &Value::obj(vec![
            ("rho_naive_top2", Value::num(rho_naive as f64)),
            ("rho_two_seed", Value::num(rho_two_seed as f64)),
        ]),
    )
}

pub fn fig7(cfg: &ExpConfig, engine: &Engine) -> Result<()> {
    println!("== Fig 7: SOAR spilled assignment angle correlation ==");
    let (e, idx_soar) = env(cfg, engine, cfg.soar_mode())?;
    let (rho_soar, scatter) = angle_correlation(&idx_soar, &e.ds, &e.gt);
    let idx_naive = build_index(engine, &e.ds.data, &cfg.index_config(SpillMode::Nearest))?;
    let (rho_naive, _) = angle_correlation(&idx_naive, &e.ds, &e.gt);
    let chart = AsciiChart::new(
        "Fig 7: cos θ vs cos θ' (SOAR spill)",
        "cos θ (primary)",
        "cos θ' (SOAR spill)",
    )
    .series('.', "pair", scatter);
    println!("{}", chart.render());
    println!("  pearson with SOAR  = {rho_soar:.3}");
    println!("  pearson naive      = {rho_naive:.3}");
    println!(
        "  shape: SOAR decorrelates: {}",
        if rho_soar < rho_naive {
            "OK (matches paper)"
        } else {
            "MISMATCH"
        }
    );
    write_report(
        &cfg.reports_dir,
        "fig7",
        &Value::obj(vec![
            ("rho_soar", Value::num(rho_soar as f64)),
            ("rho_naive", Value::num(rho_naive as f64)),
        ]),
    )
}

// ---------------------------------------------------------------------
// Fig 8: spilled-partition rank vs primary rank, SOAR vs naive
// ---------------------------------------------------------------------

pub fn fig8(cfg: &ExpConfig, engine: &Engine) -> Result<()> {
    println!("== Fig 8: spilled rank vs primary rank ==");
    let (e, idx_naive) = env(cfg, engine, SpillMode::Nearest)?;
    let idx_soar = build_index(engine, &e.ds.data, &cfg.index_config(cfg.soar_mode()))?;
    let curve = |idx: &SoarIndex| -> Vec<(f64, f64)> {
        let stats = collect_pair_stats(idx, &e.ds.data, &e.ds.queries, &e.gt);
        let pr: Vec<u32> = stats.iter().map(|s| s.primary_rank).collect();
        let sr: Vec<f32> = stats.iter().map(|s| s.spill_rank as f32).collect();
        rank_binned_means(&pr, &sr)
            .into_iter()
            .map(|(r, m, _)| (r as f64, m))
            .collect()
    };
    let naive = curve(&idx_naive);
    let soar_pts = curve(&idx_soar);
    let chart = AsciiChart::new(
        "Fig 8: mean RANK(q,C_π'(x),C) vs RANK(q,C_π(x),C)",
        "primary rank (log)",
        "mean spilled rank",
    )
    .log_x()
    .series('x', "no SOAR (naive spill)", naive.clone())
    .series('o', "SOAR", soar_pts.clone());
    println!("{}", chart.render());
    // Shape: at the highest primary ranks, SOAR's spilled rank is lower.
    let tail = |pts: &[(f64, f64)]| pts.last().map(|p| p.1).unwrap_or(0.0);
    println!(
        "  tail spilled rank: naive {:.1} vs SOAR {:.1}: {}",
        tail(&naive),
        tail(&soar_pts),
        if tail(&soar_pts) < tail(&naive) {
            "OK (matches paper)"
        } else {
            "MISMATCH"
        }
    );
    write_report(
        &cfg.reports_dir,
        "fig8",
        &Value::obj(vec![
            ("naive", series_json(&naive)),
            ("soar", series_json(&soar_pts)),
        ]),
    )
}

// ---------------------------------------------------------------------
// Fig 9: λ sweep — distortion vs score correlation
// ---------------------------------------------------------------------

pub fn fig9(cfg: &ExpConfig, engine: &Engine) -> Result<()> {
    println!("== Fig 9: λ sweep (distortion vs score correlation) ==");
    let ds = cfg.dataset();
    // One fixed VQ index; only the spilled assignment varies with λ.
    let base = build_index(engine, &ds.data, &cfg.index_config(SpillMode::None))?;
    let centroids = base.centroids();
    let primary: Vec<u32> = base.assignments.iter().map(|a| a[0]).collect();
    let lambdas: &[f32] = if cfg.quick {
        &[0.0, 1.0, 4.0]
    } else {
        &[0.0, 0.25, 0.5, 1.0, 1.5, 2.0, 3.0, 4.0]
    };
    let mut distortion_pts = Vec::new();
    let mut corr_pts = Vec::new();
    let mut rows = Vec::new();
    for &lam in lambdas {
        let assigns = soar::assign_spills(
            engine,
            &ds.data,
            centroids,
            &primary,
            SpillMode::Soar { lambda: lam },
            1,
        )?;
        // E‖r'‖² and mean residual cosine (Lemma 3.2: ρ over uniform
        // sphere queries = ⟨r̂, r̂'⟩).
        let mut dist = 0.0f64;
        let mut rho = 0.0f64;
        for (i, a) in assigns.iter().enumerate() {
            let r = crate::index::residual(ds.data.row(i), centroids, a[0]);
            let r2 = crate::index::residual(ds.data.row(i), centroids, a[1]);
            dist += crate::linalg::dot(&r2, &r2) as f64;
            rho += crate::linalg::cosine(&r, &r2) as f64;
        }
        dist /= ds.n() as f64;
        rho /= ds.n() as f64;
        distortion_pts.push((lam as f64, dist));
        corr_pts.push((lam as f64, rho));
        rows.push(vec![
            format!("{lam}"),
            format!("{dist:.5}"),
            format!("{rho:.4}"),
        ]);
    }
    println!(
        "{}",
        render_table(&["λ", "E‖r'‖² (distortion)", "ρ_{⟨q,r⟩,⟨q,r'⟩} (Lemma 3.2)"], &rows)
    );
    let rising_dist = distortion_pts.last().unwrap().1 >= distortion_pts[0].1;
    let falling_rho = corr_pts.last().unwrap().1 <= corr_pts[0].1;
    println!(
        "  shape: distortion rises with λ: {} | correlation falls with λ: {}",
        if rising_dist { "OK" } else { "MISMATCH" },
        if falling_rho { "OK" } else { "MISMATCH" }
    );
    write_report(
        &cfg.reports_dir,
        "fig9",
        &Value::obj(vec![
            ("distortion", series_json(&distortion_pts)),
            ("score_correlation", series_json(&corr_pts)),
        ]),
    )
}

// ---------------------------------------------------------------------
// Fig 6 + Table 2: KMR curves
// ---------------------------------------------------------------------

pub fn kmr_experiment(cfg: &ExpConfig, engine: &Engine) -> Result<()> {
    // The paper's Table 2 reports R@100; deep neighbor lists are exactly
    // where the hard pairs live.
    let k = if cfg.quick { 20 } else { cfg.k.max(100) };
    println!("== Fig 6 / Table 2: KMR curves (R@{k}) ==");
    let ds = cfg.dataset();
    let gt = ground_truth_mips(&ds.data, &ds.queries, k);
    let modes = [
        ("No Spilling", SpillMode::None),
        ("Spilling, No SOAR", SpillMode::Nearest),
        ("SOAR", cfg.soar_mode()),
    ];
    let mut curves = Vec::new();
    let mut results = Vec::new();
    for (name, mode) in &modes {
        let idx = build_index(engine, &ds.data, &cfg.index_config(*mode))?;
        let kmr = compute_kmr(&idx, &ds.queries, &gt);
        curves.push((
            *name,
            kmr.curve(40)
                .into_iter()
                .map(|(c, r)| (c as f64, r))
                .collect::<Vec<_>>(),
        ));
        results.push((*name, kmr));
    }
    let chart = AsciiChart::new(
        "Fig 6: KMR recall vs datapoints scanned",
        "datapoints scanned (log)",
        "recall of true neighbors",
    )
    .log_x()
    .series('n', curves[0].0, curves[0].1.clone())
    .series('s', curves[1].0, curves[1].1.clone())
    .series('O', curves[2].0, curves[2].1.clone());
    println!("{}", chart.render());

    let targets = [0.80, 0.85, 0.90, 0.95];
    let mut rows = Vec::new();
    let mut rank_rows = Vec::new();
    let mut report_rows = Vec::new();
    for &t in &targets {
        let needed: Vec<Option<u64>> = results.iter().map(|(_, k)| k.points_needed(t)).collect();
        let gain = match (needed[0], needed[2]) {
            (Some(a), Some(b)) if b > 0 => Some(a as f64 / b as f64),
            _ => None,
        };
        // Mechanism-level: partitions probed (t), scale-free.
        let t_needed: Vec<Option<u32>> =
            results.iter().map(|(_, k)| k.partitions_needed(t)).collect();
        let t_gain = match (t_needed[0], t_needed[2]) {
            (Some(a), Some(b)) if b > 0 => Some(a as f64 / b as f64),
            _ => None,
        };
        rank_rows.push(vec![
            format!("{:.0}%", t * 100.0),
            t_needed[0].map_or("-".into(), |v| v.to_string()),
            t_needed[1].map_or("-".into(), |v| v.to_string()),
            t_needed[2].map_or("-".into(), |v| v.to_string()),
            t_gain.map_or("-".into(), |g| format!("{g:.2}x")),
        ]);
        rows.push(vec![
            format!("{:.0}%", t * 100.0),
            needed[0].map_or("-".into(), |v| v.to_string()),
            needed[1].map_or("-".into(), |v| v.to_string()),
            needed[2].map_or("-".into(), |v| v.to_string()),
            gain.map_or("-".into(), |g| format!("{g:.2}x")),
        ]);
        report_rows.push(Value::obj(vec![
            ("target", Value::num(t)),
            (
                "no_spill",
                needed[0].map_or(Value::Null, |v| Value::num(v as f64)),
            ),
            (
                "nearest",
                needed[1].map_or(Value::Null, |v| Value::num(v as f64)),
            ),
            (
                "soar",
                needed[2].map_or(Value::Null, |v| Value::num(v as f64)),
            ),
            ("gain", gain.map_or(Value::Null, Value::num)),
        ]));
    }
    println!(
        "{}",
        render_table(
            &[
                "Recall target",
                "No Spilling",
                "Spilling, No SOAR",
                "SOAR",
                "KMR gain (SOAR/none)"
            ],
            &rows
        )
    );
    println!("Mechanism view — partitions probed (t) to reach target (scale-free):");
    println!(
        "{}",
        render_table(
            &[
                "Recall target",
                "No Spilling",
                "Spilling, No SOAR",
                "SOAR",
                "rank gain (SOAR/none)"
            ],
            &rank_rows
        )
    );
    println!(
        "  NOTE: the paper's weighted gains >1 appear at ≥1M-point scale (its\n\
         smallest Table 2 corpus); at laptop scale the 2x partition-size\n\
         penalty of spilling outweighs the rank improvement (Fig 10 trend).\n\
         The rank gain above isolates the §3.4 mechanism itself."
    );
    write_report(
        &cfg.reports_dir,
        "kmr_table2",
        &Value::obj(vec![("rows", Value::Arr(report_rows))]),
    )
}

// ---------------------------------------------------------------------
// Fig 10: gain vs dataset size and recall target
// ---------------------------------------------------------------------

pub fn fig10(cfg: &ExpConfig, engine: &Engine) -> Result<()> {
    println!("== Fig 10: SOAR gain vs dataset size / recall target ==");
    let sizes: Vec<usize> = if cfg.quick {
        vec![2000, 8000]
    } else {
        vec![2000, 5000, 10_000, 20_000, 50_000]
    };
    let targets = [0.80, 0.90, 0.95];
    let mut series: Vec<(f64, Vec<(f64, f64)>)> =
        targets.iter().map(|&t| (t, Vec::new())).collect();
    let mut rows = Vec::new();
    for &n in &sizes {
        // Fixed 400 points/partition, per the paper's protocol.
        let sub = ExpConfig {
            n,
            num_queries: cfg.num_queries.min(n / 20).max(30),
            ..ExpConfig {
                reports_dir: cfg.reports_dir.clone(),
                ..*cfg
            }
        };
        let ds = sub.dataset();
        let kk = if cfg.quick { 20 } else { sub.k.max(100) };
        let gt = ground_truth_mips(&ds.data, &ds.queries, kk);
        let idx_none = build_index(engine, &ds.data, &sub.index_config(SpillMode::None))?;
        let idx_soar = build_index(engine, &ds.data, &sub.index_config(sub.soar_mode()))?;
        let kmr_none = compute_kmr(&idx_none, &ds.queries, &gt);
        let kmr_soar = compute_kmr(&idx_soar, &ds.queries, &gt);
        let mut row = vec![n.to_string()];
        for (i, &t) in targets.iter().enumerate() {
            let ratio = match (kmr_none.points_needed(t), kmr_soar.points_needed(t)) {
                (Some(a), Some(b)) if b > 0 => a as f64 / b as f64,
                _ => f64::NAN,
            };
            series[i].1.push((n as f64, ratio));
            row.push(format!("{ratio:.2}x"));
        }
        rows.push(row);
    }
    println!(
        "{}",
        render_table(&["n", "gain @80%", "gain @90%", "gain @95%"], &rows)
    );
    let chart = AsciiChart::new(
        "Fig 10: points-scanned ratio (no-SOAR / SOAR)",
        "dataset size (log)",
        "ratio (higher = SOAR better)",
    )
    .log_x()
    .series('8', "recall 80%", series[0].1.clone())
    .series('9', "recall 90%", series[1].1.clone())
    .series('5', "recall 95%", series[2].1.clone());
    println!("{}", chart.render());
    let report = Value::obj(
        series
            .iter()
            .map(|(t, pts)| {
                (
                    match *t {
                        x if x == 0.80 => "gain_at_80",
                        x if x == 0.90 => "gain_at_90",
                        _ => "gain_at_95",
                    },
                    series_json(pts),
                )
            })
            .collect(),
    );
    write_report(&cfg.reports_dir, "fig10", &report)
}

// ---------------------------------------------------------------------
// Fig 11: end-to-end recall–QPS curves
// ---------------------------------------------------------------------

pub fn fig11(cfg: &ExpConfig, engine: &Engine) -> Result<()> {
    println!("== Fig 11: recall@10 vs QPS (single thread) ==");
    let ds = cfg.dataset();
    let gt = ground_truth_mips(&ds.data, &ds.queries, 10);
    let top_ts: Vec<usize> = if cfg.quick {
        vec![1, 2, 4, 8, 16]
    } else {
        vec![1, 2, 3, 4, 6, 8, 12, 16, 24, 32]
    };
    let rbs: Vec<usize> = vec![50, 150, 400];
    let mut all = Vec::new();
    for (name, mode) in [
        ("no-spill VQ", SpillMode::None),
        ("spill no-SOAR", SpillMode::Nearest),
        ("SOAR", cfg.soar_mode()),
    ] {
        let idx = build_index(engine, &ds.data, &cfg.index_config(mode))?;
        let pts = recall_curve(&idx, engine, &ds.queries, &gt, 10, &top_ts, &rbs);
        let frontier = pareto_frontier(&pts);
        all.push((name, frontier));
    }
    let chart_series: Vec<(char, &str, Vec<(f64, f64)>)> = all
        .iter()
        .zip(['n', 's', 'O'])
        .map(|((name, frontier), glyph)| {
            (
                glyph,
                *name,
                frontier.iter().map(|p| (p.recall, p.qps)).collect(),
            )
        })
        .collect();
    let mut chart = AsciiChart::new(
        "Fig 11: recall@10 vs QPS pareto frontier",
        "recall@10",
        "QPS (single thread)",
    );
    for (g, name, pts) in &chart_series {
        chart = chart.series(*g, name, pts.clone());
    }
    println!("{}", chart.render());
    let mut rows = Vec::new();
    for target in [0.8, 0.9, 0.95] {
        let mut row = vec![format!("{:.0}%", target * 100.0)];
        for (_, frontier) in &all {
            row.push(
                qps_at_recall(frontier, target)
                    .map_or("-".into(), |q| format!("{q:.0}")),
            );
        }
        rows.push(row);
    }
    println!(
        "{}",
        render_table(
            &["recall@10 target", "no-spill QPS", "no-SOAR spill QPS", "SOAR QPS"],
            &rows
        )
    );
    let report = Value::obj(
        all.iter()
            .map(|(name, frontier)| {
                (
                    *name,
                    series_json(
                        &frontier
                            .iter()
                            .map(|p| (p.recall, p.qps))
                            .collect::<Vec<_>>(),
                    ),
                )
            })
            .collect(),
    );
    write_report(&cfg.reports_dir, "fig11", &report)
}

// ---------------------------------------------------------------------
// Fig 12: cost-normalized throughput comparison
// ---------------------------------------------------------------------

pub fn fig12(cfg: &ExpConfig, engine: &Engine) -> Result<()> {
    use crate::eval::cost_model::{paper_ours, paper_submissions, ratio_table};
    println!("== Fig 12: throughput per dollar (Appendix A.4 re-tabulation) ==");
    // Measure our SOAR engine's QPS at 90% recall@10 on the synthetic
    // corpus; reported alongside the paper's own billion-scale number.
    let ds = cfg.dataset();
    let gt = ground_truth_mips(&ds.data, &ds.queries, 10);
    let idx = build_index(engine, &ds.data, &cfg.index_config(cfg.soar_mode()))?;
    let pts = recall_curve(
        &idx,
        engine,
        &ds.queries,
        &gt,
        10,
        &[1, 2, 4, 8, 16, 32],
        &[100, 400],
    );
    let frontier = pareto_frontier(&pts);
    let measured = qps_at_recall(&frontier, 0.9).unwrap_or(0.0);
    println!(
        "  measured single-thread QPS @90% recall@10 on {}: {measured:.0}",
        ds.name
    );
    println!("  (paper 'Ours' rows below use the paper's reported billion-scale QPS)");

    let mut subs = paper_submissions();
    subs.push(paper_ours());
    for (title, capex) in [("Fig 12a: QPS per capex $", true), ("Fig 12b: QPS per cloud $/mo", false)]
    {
        let rows_raw = ratio_table(&subs, capex);
        let rows: Vec<Vec<String>> = rows_raw
            .iter()
            .map(|(n, s, t)| vec![n.clone(), format!("{s:.3}"), format!("{t:.3}")])
            .collect();
        println!("{title}");
        println!(
            "{}",
            render_table(&["Algorithm", "MS-SPACEV", "MS-Turing"], &rows)
        );
        let ours = rows_raw.iter().find(|r| r.0.contains("Ours")).unwrap();
        let leads = rows_raw
            .iter()
            .all(|r| r.0.contains("Ours") || (ours.1 > r.1 && ours.2 > r.2));
        println!(
            "  shape: SOAR leads the ranking: {}",
            if leads { "OK (matches paper)" } else { "MISMATCH" }
        );
    }
    write_report(
        &cfg.reports_dir,
        "fig12",
        &Value::obj(vec![
            ("measured_qps_at_90", Value::num(measured)),
            (
                "capex_rows",
                Value::Arr(
                    ratio_table(&subs, true)
                        .into_iter()
                        .map(|(n, s, t)| {
                            Value::obj(vec![
                                ("name", Value::str(n)),
                                ("spacev", Value::num(s)),
                                ("turing", Value::num(t)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ]),
    )
}

// ---------------------------------------------------------------------
// Table 1: memory consumption
// ---------------------------------------------------------------------

pub fn table1(cfg: &ExpConfig, engine: &Engine) -> Result<()> {
    use crate::index::serialize::memory_report;
    println!("== Table 1: index memory, no-SOAR vs SOAR ==");
    let ds = cfg.dataset();
    let idx_none = build_index(engine, &ds.data, &cfg.index_config(SpillMode::None))?;
    let idx_soar = build_index(engine, &ds.data, &cfg.index_config(cfg.soar_mode()))?;
    let m_none = memory_report(&idx_none);
    let m_soar = memory_report(&idx_soar);
    let delta = (m_soar.total_bytes as f64 - m_none.total_bytes as f64)
        / m_none.total_bytes as f64;
    let rows = vec![
        vec![
            ds.name.clone(),
            format!("{:.2} MB", m_none.total_bytes as f64 / 1e6),
            format!(
                "{:.2} MB (+{:.1}%)",
                m_soar.total_bytes as f64 / 1e6,
                delta * 100.0
            ),
            format!("{:.1}%", m_soar.analytic_overhead_int8 * 100.0),
        ],
    ];
    println!(
        "{}",
        render_table(
            &["Dataset", "No SOAR", "With SOAR", "analytic §3.5 estimate"],
            &rows
        )
    );
    println!(
        "  breakdown (SOAR): centroids {}K ids {}K codes {}K codebooks {}K int8 {}K",
        m_soar.centroids_bytes / 1024,
        m_soar.posting_id_bytes / 1024,
        m_soar.pq_code_bytes / 1024,
        m_soar.pq_codebook_bytes / 1024,
        m_soar.int8_bytes / 1024
    );
    println!(
        "  shape: overhead small & near analytic: {}",
        if delta < 0.35 { "OK (matches paper)" } else { "MISMATCH" }
    );
    write_report(
        &cfg.reports_dir,
        "table1",
        &Value::obj(vec![
            ("no_soar_bytes", Value::num(m_none.total_bytes as f64)),
            ("soar_bytes", Value::num(m_soar.total_bytes as f64)),
            ("relative_increase", Value::num(delta)),
            (
                "analytic_estimate",
                Value::num(m_soar.analytic_overhead_int8),
            ),
        ]),
    )
}

/// Run every experiment in DESIGN.md §4 order.
pub fn run_all(cfg: &ExpConfig, engine: &Engine) -> Result<()> {
    fig1(cfg, engine)?;
    fig2(cfg, engine)?;
    fig4(cfg, engine)?;
    fig7(cfg, engine)?;
    fig8(cfg, engine)?;
    fig9(cfg, engine)?;
    kmr_experiment(cfg, engine)?;
    fig10(cfg, engine)?;
    fig11(cfg, engine)?;
    fig12(cfg, engine)?;
    table1(cfg, engine)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::tempdir::TempDir;

    fn tiny(dir: &TempDir) -> ExpConfig {
        ExpConfig {
            n: 1200,
            dim: 16,
            num_queries: 20,
            k: 5,
            reports_dir: dir.path().to_path_buf(),
            quick: true,
            ..Default::default()
        }
    }

    #[test]
    fn all_experiments_run_and_emit_reports() {
        let dir = TempDir::new().unwrap();
        let cfg = tiny(&dir);
        let engine = Engine::cpu();
        run_all(&cfg, &engine).unwrap();
        for name in [
            "fig1", "fig2", "fig4", "fig7", "fig8", "fig9", "kmr_table2", "fig10",
            "fig11", "fig12", "table1",
        ] {
            let path = dir.join(&format!("{name}.json"));
            assert!(path.exists(), "{name}.json missing");
            let text = std::fs::read_to_string(&path).unwrap();
            assert!(Value::parse(&text).is_ok(), "{name}.json unparseable");
        }
    }
}
