//! Evaluation drivers that regenerate every table and figure of the paper.
//!
//! Each submodule produces one family of artifacts; the CLI
//! (`soar experiments <id>`) and the `examples/` binaries call into these.
//! DESIGN.md §4 maps experiment ids to paper figures/tables:
//!
//! * [`experiments`] — one driver per figure/table (Figs 1–12, Tables 1–2),
//! * [`recall`]      — recall–QPS sweeps + Pareto reduction (Fig 11),
//! * [`cost_model`]  — Appendix A.4 pricing tables (Fig 12),
//! * [`plot`]        — ASCII charts, table rendering, JSON reports.

pub mod cost_model;
pub mod experiments;
pub mod plot;
pub mod recall;

pub use experiments::ExpConfig;
pub use recall::{pareto_frontier, qps_at_recall, recall_curve, recall_curve_with, RecallPoint};
