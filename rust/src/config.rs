//! Configuration types for index construction, search, and serving.
//!
//! All configs round-trip through JSON (`util::json`) so experiment
//! drivers and the CLI can persist/load them alongside results.

use crate::error::{Error, Result};
use crate::quant::{KMeansConfig, PqConfig};
use crate::util::json::Value;

/// How datapoints spill into additional partitions.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum SpillMode {
    /// Standard VQ: one partition per datapoint.
    None,
    /// Naive spilling: next-closest centroids by Euclidean distance
    /// (the Fig 3/4a strawman).
    Nearest,
    /// Spilling with Orthogonality-Amplified Residuals (the paper):
    /// assignment loss ‖r'‖² + λ‖proj_r r'‖².
    Soar {
        /// The λ of Theorem 3.1.
        lambda: f32,
    },
}

impl SpillMode {
    /// Short tag used in reports.
    pub fn tag(&self) -> String {
        match self {
            SpillMode::None => "none".into(),
            SpillMode::Nearest => "nearest".into(),
            SpillMode::Soar { lambda } => format!("soar(λ={lambda})"),
        }
    }
}

/// Index construction parameters.
#[derive(Clone, Debug)]
pub struct IndexConfig {
    /// Number of VQ partitions (c).
    pub num_partitions: usize,
    /// Spilling policy.
    pub spill: SpillMode,
    /// Number of *additional* assignments per datapoint (§3.5.1; the
    /// paper's experiments use 1). Ignored when `spill == None`.
    pub num_spills: usize,
    /// VQ (k-means) training parameters; `k` is overridden by
    /// `num_partitions`.
    pub kmeans: KMeansConfig,
    /// PQ parameters for the residual codes.
    pub pq: PqConfig,
    /// Keep int8 rerank vectors (the "highest-bitrate representation").
    pub store_int8: bool,
    pub seed: u64,
}

impl Default for IndexConfig {
    fn default() -> Self {
        IndexConfig {
            num_partitions: 64,
            spill: SpillMode::Soar { lambda: 1.0 },
            num_spills: 1,
            kmeans: KMeansConfig::default(),
            pq: PqConfig::default(),
            store_int8: true,
            seed: 42,
        }
    }
}

impl IndexConfig {
    /// Partitions sized for ~400 points each — the paper's Fig 10 ratio.
    pub fn for_dataset(n: usize, spill: SpillMode) -> IndexConfig {
        IndexConfig {
            num_partitions: (n / 400).max(4),
            spill,
            ..Default::default()
        }
    }

    /// Validate against a dataset shape.
    pub fn validate(&self, n: usize, dim: usize) -> Result<()> {
        if self.num_partitions == 0 {
            return Err(Error::Config("num_partitions must be > 0".into()));
        }
        if self.num_partitions > n {
            return Err(Error::Config(format!(
                "num_partitions {} > dataset size {n}",
                self.num_partitions
            )));
        }
        if self.pq.dims_per_subspace == 0 || self.pq.dims_per_subspace > dim {
            return Err(Error::Config(format!(
                "pq.dims_per_subspace {} invalid for dim {dim}",
                self.pq.dims_per_subspace
            )));
        }
        if self.spill != SpillMode::None && self.num_spills == 0 {
            return Err(Error::Config(
                "num_spills must be ≥ 1 when spilling is enabled".into(),
            ));
        }
        if self.num_spills >= self.num_partitions {
            return Err(Error::Config(format!(
                "num_spills {} must be < num_partitions {}",
                self.num_spills, self.num_partitions
            )));
        }
        Ok(())
    }

    /// Total assignments per datapoint.
    pub fn assignments_per_point(&self) -> usize {
        match self.spill {
            SpillMode::None => 1,
            _ => 1 + self.num_spills,
        }
    }

    /// JSON encoding (persisted inside the binary index format and next to
    /// experiment reports).
    pub fn to_json(&self) -> Value {
        let spill = match self.spill {
            SpillMode::None => Value::str("none"),
            SpillMode::Nearest => Value::str("nearest"),
            SpillMode::Soar { lambda } => Value::obj(vec![
                ("mode", Value::str("soar")),
                ("lambda", Value::num(lambda as f64)),
            ]),
        };
        Value::obj(vec![
            ("num_partitions", Value::num(self.num_partitions as f64)),
            ("spill", spill),
            ("num_spills", Value::num(self.num_spills as f64)),
            (
                "kmeans",
                Value::obj(vec![
                    ("k", Value::num(self.kmeans.k as f64)),
                    ("iters", Value::num(self.kmeans.iters as f64)),
                    ("seed", Value::num(self.kmeans.seed as f64)),
                    ("train_sample", Value::num(self.kmeans.train_sample as f64)),
                    (
                        "anisotropic_eta",
                        Value::num(self.kmeans.anisotropic_eta as f64),
                    ),
                ]),
            ),
            (
                "pq",
                Value::obj(vec![
                    (
                        "dims_per_subspace",
                        Value::num(self.pq.dims_per_subspace as f64),
                    ),
                    ("train_iters", Value::num(self.pq.train_iters as f64)),
                    ("seed", Value::num(self.pq.seed as f64)),
                    ("train_sample", Value::num(self.pq.train_sample as f64)),
                ]),
            ),
            ("store_int8", Value::Bool(self.store_int8)),
            ("seed", Value::num(self.seed as f64)),
        ])
    }

    /// Inverse of [`IndexConfig::to_json`].
    pub fn from_json(v: &Value) -> Result<IndexConfig> {
        let field = |obj: &Value, key: &str| -> Result<f64> {
            obj.get(key)
                .and_then(|x| x.as_f64())
                .ok_or_else(|| Error::Config(format!("missing numeric field {key}")))
        };
        let spill = match v.get("spill") {
            Some(Value::Str(s)) if s == "none" => SpillMode::None,
            Some(Value::Str(s)) if s == "nearest" => SpillMode::Nearest,
            Some(obj @ Value::Obj(_)) if obj.get("mode").and_then(|m| m.as_str()) == Some("soar") => {
                SpillMode::Soar {
                    lambda: field(obj, "lambda")? as f32,
                }
            }
            other => {
                return Err(Error::Config(format!("bad spill mode: {other:?}")));
            }
        };
        let km = v
            .get("kmeans")
            .ok_or_else(|| Error::Config("missing kmeans".into()))?;
        let pq = v
            .get("pq")
            .ok_or_else(|| Error::Config("missing pq".into()))?;
        Ok(IndexConfig {
            num_partitions: field(v, "num_partitions")? as usize,
            spill,
            num_spills: field(v, "num_spills")? as usize,
            kmeans: KMeansConfig {
                k: field(km, "k")? as usize,
                iters: field(km, "iters")? as usize,
                seed: field(km, "seed")? as u64,
                train_sample: field(km, "train_sample")? as usize,
                anisotropic_eta: field(km, "anisotropic_eta")? as f32,
            },
            pq: PqConfig {
                dims_per_subspace: field(pq, "dims_per_subspace")? as usize,
                train_iters: field(pq, "train_iters")? as usize,
                seed: field(pq, "seed")? as u64,
                train_sample: field(pq, "train_sample")? as usize,
            },
            store_int8: v
                .get("store_int8")
                .and_then(|b| b.as_bool())
                .ok_or_else(|| Error::Config("missing store_int8".into()))?,
            seed: field(v, "seed")? as u64,
        })
    }
}

/// Mutation / compaction policy for the segmented mutable index
/// (`index::MutableIndex`).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct MutableConfig {
    /// Live rows the delta segment may hold before a mutation triggers an
    /// automatic compaction (delta merged into the sealed segments).
    pub delta_capacity: usize,
    /// Tombstone pressure that triggers compaction: compact when
    /// `tombstones > tombstone_ratio * sealed_rows`.
    pub tombstone_ratio: f32,
    /// Run the compaction triggers above automatically inside
    /// `upsert`/`delete`. When `false`, compaction only happens via an
    /// explicit `compact()` call.
    pub auto_compact: bool,
    /// Group-commit window: publish a fresh snapshot only after this many
    /// mutations have accumulated (1 = publish per mutation, today's
    /// behavior). Single-row upsert streams amortize the
    /// O(delta + id_space/64) publish cost across the window; call
    /// `MutableIndex::flush` for read-your-writes before the window
    /// fills. Sealing and compaction always publish immediately.
    pub publish_coalesce: usize,
    /// Time bound on the group-commit window, in microseconds (0 =
    /// unbounded). When set, a background timer publishes any buffered
    /// mutations within this delay even if the count window never fills —
    /// a lone upsert becomes visible within T µs instead of waiting for
    /// `publish_coalesce − 1` followers or an explicit flush.
    pub publish_max_delay_us: u64,
}

impl Default for MutableConfig {
    fn default() -> Self {
        MutableConfig {
            delta_capacity: 4096,
            tombstone_ratio: 0.25,
            auto_compact: true,
            publish_coalesce: 1,
            publish_max_delay_us: 0,
        }
    }
}

impl MutableConfig {
    pub fn validate(&self) -> Result<()> {
        if self.delta_capacity == 0 {
            return Err(Error::Config("delta_capacity must be ≥ 1".into()));
        }
        if self.tombstone_ratio.is_nan() || self.tombstone_ratio <= 0.0 {
            return Err(Error::Config(format!(
                "tombstone_ratio must be > 0, got {}",
                self.tombstone_ratio
            )));
        }
        if self.publish_coalesce == 0 {
            return Err(Error::Config("publish_coalesce must be ≥ 1".into()));
        }
        Ok(())
    }

    /// JSON encoding (persisted next to snapshots and experiment reports).
    pub fn to_json(&self) -> Value {
        Value::obj(vec![
            ("delta_capacity", Value::num(self.delta_capacity as f64)),
            ("tombstone_ratio", Value::num(self.tombstone_ratio as f64)),
            ("auto_compact", Value::Bool(self.auto_compact)),
            ("publish_coalesce", Value::num(self.publish_coalesce as f64)),
            (
                "publish_max_delay_us",
                Value::num(self.publish_max_delay_us as f64),
            ),
        ])
    }

    /// Inverse of [`MutableConfig::to_json`]. `publish_coalesce` and
    /// `publish_max_delay_us` are optional (configs persisted before the
    /// group-commit window default to 1 / 0, the old publish-per-mutation
    /// behavior).
    pub fn from_json(v: &Value) -> Result<MutableConfig> {
        let num = |key: &str| -> Result<f64> {
            v.get(key)
                .and_then(|x| x.as_f64())
                .ok_or_else(|| Error::Config(format!("missing numeric field {key}")))
        };
        let cfg = MutableConfig {
            delta_capacity: v
                .get("delta_capacity")
                .and_then(|x| x.as_usize())
                .ok_or_else(|| {
                    Error::Config("delta_capacity must be a non-negative integer".into())
                })?,
            tombstone_ratio: num("tombstone_ratio")? as f32,
            auto_compact: v
                .get("auto_compact")
                .and_then(|b| b.as_bool())
                .ok_or_else(|| Error::Config("missing auto_compact".into()))?,
            publish_coalesce: match v.get("publish_coalesce") {
                Some(x) => x.as_usize().ok_or_else(|| {
                    Error::Config("publish_coalesce must be a positive integer".into())
                })?,
                None => 1,
            },
            publish_max_delay_us: match v.get("publish_max_delay_us") {
                Some(x) => x.as_usize().ok_or_else(|| {
                    Error::Config("publish_max_delay_us must be a non-negative integer".into())
                })? as u64,
                None => 0,
            },
        };
        cfg.validate()?;
        Ok(cfg)
    }
}

/// Policy for the per-shard background **maintenance engine**: when the
/// worker retrains a drifted shard on its own and when it re-encodes
/// small stale-model runs into the active model (model-converging
/// compaction).
///
/// The drift signal is the write path's EWMA of per-upsert primary
/// assignment loss ‖x − c_primary‖² divided by the active model's
/// recorded training loss (see `QuantModel::training_loss`). A ratio of
/// 1.0 means new rows quantize exactly as well as the rows the model was
/// trained on; the engine fires a staged retrain when the ratio crosses
/// `drift_threshold`, at most once per `retrain_cooldown_ms`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct MaintenanceConfig {
    /// Let the background worker fire `begin_retrain → train →
    /// install_retrain` on its own when the drift ratio crosses
    /// `drift_threshold`. Off by default: retrains are operator-driven
    /// unless the deployment opts in.
    pub auto_retrain: bool,
    /// Drift ratio (EWMA upsert loss / model training loss) at which an
    /// automatic retrain fires.
    pub drift_threshold: f32,
    /// Ignore the drift signal until this many upserts have fed the EWMA
    /// since the active model was installed (a handful of unlucky rows
    /// must not trigger a full retrain).
    pub min_drift_samples: u64,
    /// Minimum time between automatic retrain *attempts* on one shard,
    /// in milliseconds. Cooldown is measured from the attempt, not the
    /// install, so a repeatedly-aborting retrain cannot hot-loop.
    pub retrain_cooldown_ms: u64,
    /// During quiet periods (no compaction pressure, no drift trigger),
    /// re-encode small stale-model runs into the active model so
    /// long-lived mixed-model snapshots converge to a single model
    /// without a full retrain.
    pub converge_compact: bool,
    /// Largest stale run (stored rows) the converging compaction will
    /// re-encode; bigger runs wait for the next full retrain.
    pub converge_max_rows: usize,
}

impl Default for MaintenanceConfig {
    fn default() -> Self {
        MaintenanceConfig {
            auto_retrain: false,
            drift_threshold: 1.5,
            min_drift_samples: 256,
            retrain_cooldown_ms: 60_000,
            converge_compact: false,
            converge_max_rows: 4096,
        }
    }
}

impl MaintenanceConfig {
    pub fn validate(&self) -> Result<()> {
        if !self.drift_threshold.is_finite() || self.drift_threshold <= 0.0 {
            return Err(Error::Config(format!(
                "drift_threshold must be a positive finite number, got {}",
                self.drift_threshold
            )));
        }
        if self.converge_compact && self.converge_max_rows == 0 {
            return Err(Error::Config(
                "converge_max_rows must be ≥ 1 when converge_compact is set".into(),
            ));
        }
        Ok(())
    }

    /// JSON encoding (persisted inside the v3 collection manifest).
    pub fn to_json(&self) -> Value {
        Value::obj(vec![
            ("auto_retrain", Value::Bool(self.auto_retrain)),
            ("drift_threshold", Value::num(self.drift_threshold as f64)),
            ("min_drift_samples", Value::num(self.min_drift_samples as f64)),
            (
                "retrain_cooldown_ms",
                Value::num(self.retrain_cooldown_ms as f64),
            ),
            ("converge_compact", Value::Bool(self.converge_compact)),
            ("converge_max_rows", Value::num(self.converge_max_rows as f64)),
        ])
    }

    /// Inverse of [`MaintenanceConfig::to_json`]. Every field is
    /// optional — an *absent* field takes its default (manifests written
    /// before that knob existed) — but a field that is present with the
    /// wrong type is an error, not a silent fallback to the default
    /// policy.
    pub fn from_json(v: &Value) -> Result<MaintenanceConfig> {
        let bool_field = |key: &str, default: bool| -> Result<bool> {
            match v.get(key) {
                None => Ok(default),
                Some(x) => x
                    .as_bool()
                    .ok_or_else(|| Error::Config(format!("{key} must be a boolean"))),
            }
        };
        let num_field = |key: &str, default: f64| -> Result<f64> {
            match v.get(key) {
                None => Ok(default),
                Some(x) => x
                    .as_f64()
                    .ok_or_else(|| Error::Config(format!("{key} must be a number"))),
            }
        };
        let d = MaintenanceConfig::default();
        let cfg = MaintenanceConfig {
            auto_retrain: bool_field("auto_retrain", d.auto_retrain)?,
            drift_threshold: num_field("drift_threshold", d.drift_threshold as f64)? as f32,
            min_drift_samples: num_field("min_drift_samples", d.min_drift_samples as f64)? as u64,
            retrain_cooldown_ms: num_field("retrain_cooldown_ms", d.retrain_cooldown_ms as f64)?
                as u64,
            converge_compact: bool_field("converge_compact", d.converge_compact)?,
            converge_max_rows: match v.get("converge_max_rows") {
                None => d.converge_max_rows,
                Some(x) => x.as_usize().ok_or_else(|| {
                    Error::Config("converge_max_rows must be a non-negative integer".into())
                })?,
            },
        };
        cfg.validate()?;
        Ok(cfg)
    }
}

/// When the per-shard write-ahead log fsyncs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FsyncPolicy {
    /// fsync after every appended record: an acknowledged write is
    /// durable the moment `upsert`/`delete` returns. Strongest
    /// guarantee, one fsync per mutation.
    Always,
    /// fsync when the publish window commits (riding the existing
    /// `publish_coalesce` / publish-timer group-commit machinery): an
    /// acknowledged write is durable once its group publishes, so the
    /// fsync cost amortizes across the window. The default.
    GroupCommit,
    /// Never fsync from the write path; the OS flushes on its own
    /// schedule. Crash-*consistent* (torn tails are detected and
    /// discarded on replay) but the unsynced tail may be lost.
    Never,
}

impl FsyncPolicy {
    /// Short tag used in the manifest and the CLI.
    pub fn tag(&self) -> &'static str {
        match self {
            FsyncPolicy::Always => "always",
            FsyncPolicy::GroupCommit => "group_commit",
            FsyncPolicy::Never => "never",
        }
    }

    /// Inverse of [`FsyncPolicy::tag`].
    pub fn from_tag(tag: &str) -> Result<FsyncPolicy> {
        match tag {
            "always" => Ok(FsyncPolicy::Always),
            "group_commit" => Ok(FsyncPolicy::GroupCommit),
            "never" => Ok(FsyncPolicy::Never),
            other => Err(Error::Config(format!("unknown fsync policy {other:?}"))),
        }
    }
}

/// Crash-safety knobs for a [`crate::index::Collection`]. The default is
/// everything **off** — exactly the pre-durability behavior (no WAL, no
/// footers, plain writes), and a default-valued config is omitted from
/// the manifest JSON so legacy manifests stay byte-identical.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DurabilityConfig {
    /// Append every upsert/delete to a per-shard checksummed WAL and
    /// replay its tail on `Collection::open`. Also switches saves to
    /// durable installs (checksummed footer + atomic rename).
    pub wal: bool,
    /// WAL fsync schedule (ignored when `wal` is off).
    pub fsync: FsyncPolicy,
}

impl Default for DurabilityConfig {
    fn default() -> Self {
        DurabilityConfig {
            wal: false,
            fsync: FsyncPolicy::GroupCommit,
        }
    }
}

impl DurabilityConfig {
    /// JSON encoding (persisted inside the v3 collection manifest when
    /// non-default).
    pub fn to_json(&self) -> Value {
        Value::obj(vec![
            ("wal", Value::Bool(self.wal)),
            ("fsync", Value::str(self.fsync.tag())),
        ])
    }

    /// Inverse of [`DurabilityConfig::to_json`]. Absent fields take
    /// their defaults; present fields of the wrong type are errors.
    pub fn from_json(v: &Value) -> Result<DurabilityConfig> {
        let d = DurabilityConfig::default();
        Ok(DurabilityConfig {
            wal: match v.get("wal") {
                None => d.wal,
                Some(x) => x
                    .as_bool()
                    .ok_or_else(|| Error::Config("wal must be a boolean".into()))?,
            },
            fsync: match v.get("fsync") {
                None => d.fsync,
                Some(x) => FsyncPolicy::from_tag(
                    x.as_str()
                        .ok_or_else(|| Error::Config("fsync must be a string".into()))?,
                )?,
            },
        })
    }
}

/// How a [`crate::index::Collection`] maps a global id to one of its
/// shards. The policy is persisted in the v3 collection manifest so a
/// reloaded collection keeps routing upserts to the shard that already
/// holds each id.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ShardRouting {
    /// SplitMix64 hash of the id — uniform spread regardless of how ids
    /// were allocated (the default).
    Hash,
    /// `id % num_shards` — keeps consecutive ids on rotating shards;
    /// useful when the id space is already uniform and debuggability
    /// matters.
    Modulo,
}

impl ShardRouting {
    /// Shard index for `id` among `num_shards` shards.
    #[inline]
    pub fn shard_of(&self, id: u32, num_shards: usize) -> usize {
        debug_assert!(num_shards >= 1);
        if num_shards <= 1 {
            return 0;
        }
        match self {
            ShardRouting::Hash => {
                // SplitMix64 finalizer: stable across runs and platforms.
                let mut z = (id as u64).wrapping_add(0x9e37_79b9_7f4a_7c15);
                z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
                z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
                z ^= z >> 31;
                (z % num_shards as u64) as usize
            }
            ShardRouting::Modulo => id as usize % num_shards,
        }
    }

    /// Short tag used in reports and the manifest.
    pub fn tag(&self) -> &'static str {
        match self {
            ShardRouting::Hash => "hash",
            ShardRouting::Modulo => "modulo",
        }
    }

    /// Inverse of [`ShardRouting::tag`].
    pub fn from_tag(tag: &str) -> Result<ShardRouting> {
        match tag {
            "hash" => Ok(ShardRouting::Hash),
            "modulo" => Ok(ShardRouting::Modulo),
            other => Err(Error::Config(format!("unknown shard routing {other:?}"))),
        }
    }
}

/// Shape of a [`crate::index::Collection`]: how many shards, how ids route
/// to them, and the per-shard mutation policy.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CollectionConfig {
    /// Number of independently mutable shards (≥ 1).
    pub num_shards: usize,
    /// Id → shard routing policy.
    pub routing: ShardRouting,
    /// Mutation / compaction policy applied to every shard.
    pub mutable: MutableConfig,
    /// Spawn one background maintenance worker per shard: delta seals and
    /// sealed-segment merges run off the write path (copy-then-swap), so
    /// writers stall only for the final snapshot publish, and the worker
    /// additionally owns the `maintenance` policy (drift-triggered
    /// retrains, model-converging compaction). Disables the shards'
    /// inline `auto_compact` (the worker owns the triggers).
    pub background_compact: bool,
    /// Maintenance-engine policy (drift-triggered retraining +
    /// model-converging compaction), enforced by the background workers
    /// when `background_compact` is set and by explicit
    /// `Collection::maintenance_tick` calls otherwise.
    pub maintenance: MaintenanceConfig,
    /// Crash-safety policy (per-shard WAL + durable installs). Default
    /// off ⇒ bit-for-bit the pre-durability behavior.
    pub durability: DurabilityConfig,
}

impl Default for CollectionConfig {
    fn default() -> Self {
        CollectionConfig {
            num_shards: 1,
            routing: ShardRouting::Hash,
            mutable: MutableConfig::default(),
            background_compact: false,
            maintenance: MaintenanceConfig::default(),
            durability: DurabilityConfig::default(),
        }
    }
}

impl CollectionConfig {
    pub fn validate(&self) -> Result<()> {
        if self.num_shards == 0 {
            return Err(Error::Config("num_shards must be ≥ 1".into()));
        }
        self.mutable.validate()?;
        self.maintenance.validate()
    }

    /// Per-shard mutation config actually handed to the shards: inline
    /// auto-compaction is owned by the background workers when they run.
    pub fn shard_mutable(&self) -> MutableConfig {
        MutableConfig {
            auto_compact: self.mutable.auto_compact && !self.background_compact,
            ..self.mutable
        }
    }

    /// JSON encoding (persisted inside the v3 collection manifest). A
    /// default (all-off) durability config is omitted so manifests
    /// written by non-durable deployments stay byte-identical to the
    /// pre-durability format.
    pub fn to_json(&self) -> Value {
        let mut fields = vec![
            ("num_shards", Value::num(self.num_shards as f64)),
            ("routing", Value::str(self.routing.tag())),
            ("mutable", self.mutable.to_json()),
            ("background_compact", Value::Bool(self.background_compact)),
            ("maintenance", self.maintenance.to_json()),
        ];
        if self.durability != DurabilityConfig::default() {
            fields.push(("durability", self.durability.to_json()));
        }
        Value::obj(fields)
    }

    /// Inverse of [`CollectionConfig::to_json`]. `maintenance` is
    /// optional (v3 manifests persisted before the maintenance engine
    /// default to the conservative do-nothing policy).
    pub fn from_json(v: &Value) -> Result<CollectionConfig> {
        let cfg = CollectionConfig {
            num_shards: v
                .get("num_shards")
                .and_then(|x| x.as_usize())
                .ok_or_else(|| Error::Config("num_shards must be a positive integer".into()))?,
            routing: ShardRouting::from_tag(
                v.get("routing")
                    .and_then(|x| x.as_str())
                    .ok_or_else(|| Error::Config("missing routing".into()))?,
            )?,
            mutable: MutableConfig::from_json(
                v.get("mutable")
                    .ok_or_else(|| Error::Config("missing mutable".into()))?,
            )?,
            background_compact: v
                .get("background_compact")
                .and_then(|b| b.as_bool())
                .ok_or_else(|| Error::Config("missing background_compact".into()))?,
            maintenance: match v.get("maintenance") {
                Some(m) => MaintenanceConfig::from_json(m)?,
                None => MaintenanceConfig::default(),
            },
            durability: match v.get("durability") {
                Some(d) => DurabilityConfig::from_json(d)?,
                None => DurabilityConfig::default(),
            },
        };
        cfg.validate()?;
        Ok(cfg)
    }
}

/// Per-query search parameters.
#[derive(Clone, Copy, Debug)]
pub struct SearchParams {
    /// Neighbors to return.
    pub k: usize,
    /// Partitions to probe (t in the KMR analysis).
    pub top_t: usize,
    /// Candidates kept from the ADC stage for exact rerank.
    pub rerank_budget: usize,
}

impl Default for SearchParams {
    fn default() -> Self {
        SearchParams {
            k: 10,
            top_t: 8,
            rerank_budget: 200,
        }
    }
}

impl SearchParams {
    pub fn validate(&self) -> Result<()> {
        if self.k == 0 {
            return Err(Error::Config("k must be > 0".into()));
        }
        if self.top_t == 0 {
            return Err(Error::Config("top_t must be > 0".into()));
        }
        if self.rerank_budget < self.k {
            return Err(Error::Config(format!(
                "rerank_budget {} < k {}",
                self.rerank_budget, self.k
            )));
        }
        Ok(())
    }
}

/// Serving-stack parameters (coordinator layer).
#[derive(Clone, Copy, Debug)]
pub struct ServeConfig {
    /// Max queries fused into one scoring batch.
    pub max_batch: usize,
    /// Max time a query waits for batch-mates before the batch is flushed.
    pub max_wait_us: u64,
    /// Worker tasks draining the batch queue.
    pub workers: usize,
    /// Bounded queue depth before callers see backpressure.
    pub queue_depth: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            max_batch: 64,
            max_wait_us: 200,
            workers: 4,
            queue_depth: 4096,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_config_valid() {
        IndexConfig::default().validate(10_000, 64).unwrap();
        SearchParams::default().validate().unwrap();
    }

    #[test]
    fn rejects_bad_shapes() {
        let mut c = IndexConfig::default();
        c.num_partitions = 0;
        assert!(c.validate(100, 8).is_err());
        c.num_partitions = 200;
        assert!(c.validate(100, 8).is_err());
        c.num_partitions = 50;
        c.pq.dims_per_subspace = 9;
        assert!(c.validate(100, 8).is_err());
        c.pq.dims_per_subspace = 2;
        c.num_spills = 0;
        assert!(c.validate(100, 8).is_err());
        c.spill = SpillMode::None;
        assert!(c.validate(100, 8).is_ok());
    }

    #[test]
    fn search_params_validation() {
        let mut p = SearchParams::default();
        p.rerank_budget = 5;
        p.k = 10;
        assert!(p.validate().is_err());
        p.k = 0;
        assert!(p.validate().is_err());
    }

    #[test]
    fn assignments_per_point() {
        let mut c = IndexConfig::default();
        assert_eq!(c.assignments_per_point(), 2);
        c.num_spills = 3;
        assert_eq!(c.assignments_per_point(), 4);
        c.spill = SpillMode::None;
        assert_eq!(c.assignments_per_point(), 1);
    }

    #[test]
    fn spill_tags() {
        assert_eq!(SpillMode::None.tag(), "none");
        assert_eq!(SpillMode::Nearest.tag(), "nearest");
        assert!(SpillMode::Soar { lambda: 1.5 }.tag().contains("1.5"));
    }

    #[test]
    fn config_json_round_trip() {
        let mut c = IndexConfig::default();
        c.spill = SpillMode::Soar { lambda: 2.25 };
        c.num_spills = 3;
        c.kmeans.anisotropic_eta = 1.5;
        c.pq.dims_per_subspace = 4;
        c.store_int8 = false;
        let s = c.to_json().to_json_pretty();
        let back = IndexConfig::from_json(&crate::util::json::Value::parse(&s).unwrap()).unwrap();
        assert_eq!(back.num_partitions, c.num_partitions);
        assert_eq!(back.spill, c.spill);
        assert_eq!(back.num_spills, 3);
        assert_eq!(back.kmeans.anisotropic_eta, 1.5);
        assert_eq!(back.pq.dims_per_subspace, 4);
        assert!(!back.store_int8);
    }

    #[test]
    fn mutable_config_round_trip_and_validation() {
        let mut m = MutableConfig::default();
        m.validate().unwrap();
        m.delta_capacity = 100;
        m.tombstone_ratio = 0.5;
        m.auto_compact = false;
        let s = m.to_json().to_json();
        let back = MutableConfig::from_json(&crate::util::json::Value::parse(&s).unwrap()).unwrap();
        assert_eq!(back, m);
        m.delta_capacity = 0;
        assert!(m.validate().is_err());
        m.delta_capacity = 1;
        m.tombstone_ratio = 0.0;
        assert!(m.validate().is_err());
        // from_json rejects configs validate() would reject
        let bad = crate::util::json::Value::parse(
            "{\"delta_capacity\": 0.5, \"tombstone_ratio\": 0.25, \"auto_compact\": true}",
        )
        .unwrap();
        assert!(MutableConfig::from_json(&bad).is_err());
    }

    #[test]
    fn publish_coalesce_validation_and_default() {
        let mut m = MutableConfig::default();
        assert_eq!(m.publish_coalesce, 1);
        assert_eq!(m.publish_max_delay_us, 0);
        m.publish_coalesce = 0;
        assert!(m.validate().is_err());
        // Configs persisted before the group-commit window still parse.
        let legacy = crate::util::json::Value::parse(
            "{\"delta_capacity\": 64, \"tombstone_ratio\": 0.25, \"auto_compact\": true}",
        )
        .unwrap();
        let back = MutableConfig::from_json(&legacy).unwrap();
        assert_eq!(back.publish_coalesce, 1);
        assert_eq!(back.publish_max_delay_us, 0);
        // The time bound round-trips.
        let timed = MutableConfig {
            publish_coalesce: 64,
            publish_max_delay_us: 500,
            ..Default::default()
        };
        let s = timed.to_json().to_json();
        let back = MutableConfig::from_json(&crate::util::json::Value::parse(&s).unwrap()).unwrap();
        assert_eq!(back, timed);
    }

    #[test]
    fn shard_routing_is_stable_and_in_range() {
        for routing in [ShardRouting::Hash, ShardRouting::Modulo] {
            for shards in [1usize, 2, 3, 8] {
                for id in [0u32, 1, 7, 1000, u32::MAX] {
                    let s = routing.shard_of(id, shards);
                    assert!(s < shards);
                    assert_eq!(s, routing.shard_of(id, shards), "routing must be pure");
                }
            }
            assert_eq!(routing.shard_of(12345, 1), 0);
            assert_eq!(ShardRouting::from_tag(routing.tag()).unwrap(), routing);
        }
        assert_eq!(ShardRouting::Modulo.shard_of(7, 3), 1);
        assert!(ShardRouting::from_tag("bogus").is_err());
        // Hash routing spreads a contiguous id range across all shards.
        let mut counts = [0usize; 4];
        for id in 0..1000u32 {
            counts[ShardRouting::Hash.shard_of(id, 4)] += 1;
        }
        for (s, &c) in counts.iter().enumerate() {
            assert!(c > 150, "shard {s} got only {c}/1000 ids");
        }
    }

    #[test]
    fn collection_config_round_trip_and_validation() {
        let mut c = CollectionConfig {
            num_shards: 4,
            routing: ShardRouting::Modulo,
            mutable: MutableConfig {
                delta_capacity: 128,
                publish_coalesce: 8,
                ..Default::default()
            },
            background_compact: true,
            maintenance: MaintenanceConfig {
                auto_retrain: true,
                drift_threshold: 1.25,
                min_drift_samples: 32,
                retrain_cooldown_ms: 5_000,
                converge_compact: true,
                converge_max_rows: 512,
            },
            durability: Default::default(),
        };
        c.validate().unwrap();
        // Background workers own the compaction triggers.
        assert!(!c.shard_mutable().auto_compact);
        c.background_compact = false;
        assert!(c.shard_mutable().auto_compact);
        let s = c.to_json().to_json_pretty();
        let back =
            CollectionConfig::from_json(&crate::util::json::Value::parse(&s).unwrap()).unwrap();
        assert_eq!(back, c);
        c.num_shards = 0;
        assert!(c.validate().is_err());
    }

    #[test]
    fn maintenance_config_round_trip_defaults_and_validation() {
        let d = MaintenanceConfig::default();
        d.validate().unwrap();
        assert!(!d.auto_retrain, "autonomy must be opt-in");
        assert!(!d.converge_compact);
        // Round trip of a fully customized policy.
        let m = MaintenanceConfig {
            auto_retrain: true,
            drift_threshold: 2.0,
            min_drift_samples: 64,
            retrain_cooldown_ms: 1_000,
            converge_compact: true,
            converge_max_rows: 128,
        };
        let s = m.to_json().to_json();
        let back =
            MaintenanceConfig::from_json(&crate::util::json::Value::parse(&s).unwrap()).unwrap();
        assert_eq!(back, m);
        // A v3 manifest written before the maintenance engine carries no
        // "maintenance" object: the collection parses with the default
        // do-nothing policy.
        let legacy = CollectionConfig::default().to_json().to_json();
        let mut legacy_v = crate::util::json::Value::parse(&legacy).unwrap();
        if let crate::util::json::Value::Obj(entries) = &mut legacy_v {
            entries.remove("maintenance");
        }
        let back = CollectionConfig::from_json(&legacy_v).unwrap();
        assert_eq!(back.maintenance, MaintenanceConfig::default());
        // A present field of the wrong type is corruption, not a legacy
        // manifest: it must error, never silently fall back to defaults.
        let bad_type =
            crate::util::json::Value::parse("{\"drift_threshold\": \"2.5\"}").unwrap();
        assert!(MaintenanceConfig::from_json(&bad_type).is_err());
        let bad_type = crate::util::json::Value::parse("{\"auto_retrain\": 1}").unwrap();
        assert!(MaintenanceConfig::from_json(&bad_type).is_err());
        // Validation rejects nonsense.
        let bad = MaintenanceConfig {
            drift_threshold: 0.0,
            ..Default::default()
        };
        assert!(bad.validate().is_err());
        let bad = MaintenanceConfig {
            converge_compact: true,
            converge_max_rows: 0,
            ..Default::default()
        };
        assert!(bad.validate().is_err());
    }

    #[test]
    fn durability_config_round_trip_and_manifest_compat() {
        let d = DurabilityConfig::default();
        assert!(!d.wal, "durability must be opt-in");
        assert_eq!(d.fsync, FsyncPolicy::GroupCommit);
        // A default config leaves the manifest JSON untouched — the
        // byte-identity guarantee for non-durable deployments.
        let legacy_json = CollectionConfig::default().to_json().to_json();
        assert!(!legacy_json.contains("durability"), "{legacy_json}");
        // Non-default configs round-trip.
        for fsync in [FsyncPolicy::Always, FsyncPolicy::GroupCommit, FsyncPolicy::Never] {
            assert_eq!(FsyncPolicy::from_tag(fsync.tag()).unwrap(), fsync);
            let c = CollectionConfig {
                durability: DurabilityConfig { wal: true, fsync },
                ..Default::default()
            };
            let s = c.to_json().to_json();
            assert!(s.contains("durability"));
            let back =
                CollectionConfig::from_json(&crate::util::json::Value::parse(&s).unwrap()).unwrap();
            assert_eq!(back, c);
        }
        assert!(FsyncPolicy::from_tag("bogus").is_err());
        // Absent fields default; wrong-typed fields error.
        let empty = crate::util::json::Value::parse("{}").unwrap();
        assert_eq!(DurabilityConfig::from_json(&empty).unwrap(), d);
        let bad = crate::util::json::Value::parse("{\"wal\": 1}").unwrap();
        assert!(DurabilityConfig::from_json(&bad).is_err());
        let bad = crate::util::json::Value::parse("{\"fsync\": true}").unwrap();
        assert!(DurabilityConfig::from_json(&bad).is_err());
    }

    #[test]
    fn from_json_rejects_garbage() {
        let v = crate::util::json::Value::parse("{\"spill\": \"bogus\"}").unwrap();
        assert!(IndexConfig::from_json(&v).is_err());
    }

    #[test]
    fn for_dataset_partition_ratio() {
        let c = IndexConfig::for_dataset(100_000, SpillMode::None);
        assert_eq!(c.num_partitions, 250); // 400 points per partition
        let tiny = IndexConfig::for_dataset(100, SpillMode::None);
        assert_eq!(tiny.num_partitions, 4);
    }
}
