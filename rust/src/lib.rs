//! # soar-ann
//!
//! A production-grade reproduction of **SOAR: Improved Indexing for
//! Approximate Nearest Neighbor Search** (Sun, Simcha, Dopson, Guo,
//! Kumar — NeurIPS 2023), built as a three-layer Rust + JAX + Pallas
//! system:
//!
//! * **L1** — Pallas kernels (`python/compile/kernels/`) for the dense
//!   scoring hot-spots (centroid MIPS scoring, Theorem 3.1 SOAR loss),
//! * **L2** — JAX compute graphs (`python/compile/model.py`) AOT-lowered
//!   to HLO text artifacts,
//! * **L3** — this crate: the full indexing pipeline, multi-stage
//!   searcher, PJRT runtime that executes the artifacts, and a tokio
//!   serving coordinator (router → dynamic batcher → workers). Python
//!   never runs on the request path.
//!
//! ## Quickstart
//!
//! ```no_run
//! use soar_ann::config::{IndexConfig, SearchParams, SpillMode};
//! use soar_ann::data::synthetic::SyntheticConfig;
//! use soar_ann::index::{build_index, SearchScratch, Searcher};
//! use soar_ann::runtime::Engine;
//!
//! let ds = SyntheticConfig::glove_like(10_000, 64, 100, 42).generate();
//! let engine = Engine::auto(&soar_ann::runtime::default_artifact_dir());
//! let cfg = IndexConfig::for_dataset(ds.n(), SpillMode::Soar { lambda: 1.0 });
//! let index = build_index(&engine, &ds.data, &cfg).unwrap();
//! let searcher = Searcher::new(&index, &engine);
//! let mut scratch = SearchScratch::new(&index);
//! let (hits, stats) =
//!     searcher.search(ds.queries.row(0), &SearchParams::default(), &mut scratch);
//! println!("top hit {} (scanned {} points)", hits[0].id, stats.points_scanned);
//! ```

// Kernel-style numeric code: explicit index loops are kept where they
// mirror the math or keep multi-array access patterns obvious.
#![allow(
    clippy::needless_range_loop,
    clippy::too_many_arguments,
    clippy::type_complexity,
    clippy::field_reassign_with_default
)]

pub mod config;
pub mod coordinator;
pub mod data;
pub mod error;
pub mod eval;
pub mod index;
pub mod linalg;
pub mod quant;
pub mod runtime;
pub mod util;

pub use config::{
    CollectionConfig, IndexConfig, MutableConfig, SearchParams, ServeConfig, ShardRouting,
    SpillMode,
};
pub use error::{Error, Result};
pub use index::{
    build_index, Collection, CollectionSearcher, CollectionSnapshot, IndexSnapshot, MutableIndex,
    Search, SearchScratch, Searcher, SnapshotCell, SnapshotSearcher, SoarIndex,
};
pub use quant::QuantModel;
pub use runtime::Engine;
