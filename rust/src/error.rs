//! Library-wide error type.

use std::fmt;

/// Errors produced by the SOAR engine.
#[derive(Debug)]
pub enum Error {
    /// Configuration was internally inconsistent (bad dims, k > n, ...).
    Config(String),
    /// Dataset / index shape mismatch at an API boundary.
    Shape(String),
    /// Binary (de)serialization failure for index files.
    Serialize(String),
    /// Filesystem IO.
    Io(std::io::Error),
    /// An on-disk file failed checksum / framing verification. Always
    /// carries the path, so an operator of an S-shard collection knows
    /// *which* file to restore, and a detail string describing what
    /// failed to verify.
    Corrupt { path: String, detail: String },
    /// PJRT runtime failure (artifact load / compile / execute).
    Runtime(String),
    /// The serving coordinator was shut down or a worker died.
    Coordinator(String),
}

impl Error {
    /// A [`Error::Corrupt`] for `path`.
    pub fn corrupt(path: &std::path::Path, detail: impl Into<String>) -> Error {
        Error::Corrupt {
            path: path.display().to_string(),
            detail: detail.into(),
        }
    }

    /// Attach a file path to IO and serialize errors that lack one, so a
    /// failure in an S-shard load names the offending file. The variant
    /// shape is preserved (`Io` stays `Io`, `Serialize` stays
    /// `Serialize`) — only the message is contextualized.
    pub fn with_path(self, path: &std::path::Path) -> Error {
        match self {
            Error::Io(e) => Error::Io(std::io::Error::new(
                e.kind(),
                format!("{}: {e}", path.display()),
            )),
            Error::Serialize(m) => Error::Serialize(format!("{}: {m}", path.display())),
            other => other,
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Config(m) => write!(f, "config error: {m}"),
            Error::Shape(m) => write!(f, "shape error: {m}"),
            Error::Serialize(m) => write!(f, "serialize error: {m}"),
            Error::Io(e) => write!(f, "io error: {e}"),
            Error::Corrupt { path, detail } => {
                write!(f, "corrupt file {path}: {detail}")
            }
            Error::Runtime(m) => write!(f, "runtime error: {m}"),
            Error::Coordinator(m) => write!(f, "coordinator error: {m}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

/// Library result alias.
pub type Result<T> = std::result::Result<T, Error>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_covers_variants() {
        let cases: Vec<(Error, &str)> = vec![
            (Error::Config("x".into()), "config"),
            (Error::Shape("x".into()), "shape"),
            (Error::Serialize("x".into()), "serialize"),
            (
                Error::Io(std::io::Error::new(std::io::ErrorKind::Other, "x")),
                "io",
            ),
            (Error::Runtime("x".into()), "runtime"),
            (Error::Coordinator("x".into()), "coordinator"),
            (
                Error::Corrupt {
                    path: "shard-0001.soar".into(),
                    detail: "bad crc".into(),
                },
                "corrupt",
            ),
        ];
        for (e, frag) in cases {
            assert!(e.to_string().contains(frag), "{e}");
        }
    }

    #[test]
    fn with_path_contextualizes_io_and_serialize() {
        let p = std::path::Path::new("/tmp/shard-0002.soar");
        let io = Error::Io(std::io::Error::new(std::io::ErrorKind::NotFound, "gone"));
        let io = io.with_path(p);
        assert!(matches!(io, Error::Io(_)), "variant shape preserved");
        assert!(io.to_string().contains("shard-0002"));
        let ser = Error::Serialize("bad magic".into()).with_path(p);
        assert!(matches!(ser, Error::Serialize(_)));
        assert!(ser.to_string().contains("shard-0002"));
        // Other variants pass through untouched.
        let cfg = Error::Config("x".into()).with_path(p);
        assert!(!cfg.to_string().contains("shard-0002"));
    }

    #[test]
    fn io_error_converts() {
        fn failing() -> Result<()> {
            Err(std::io::Error::new(std::io::ErrorKind::NotFound, "gone"))?;
            Ok(())
        }
        assert!(matches!(failing(), Err(Error::Io(_))));
    }
}
