//! Library-wide error type.

use std::fmt;

/// Errors produced by the SOAR engine.
#[derive(Debug)]
pub enum Error {
    /// Configuration was internally inconsistent (bad dims, k > n, ...).
    Config(String),
    /// Dataset / index shape mismatch at an API boundary.
    Shape(String),
    /// Binary (de)serialization failure for index files.
    Serialize(String),
    /// Filesystem IO.
    Io(std::io::Error),
    /// PJRT runtime failure (artifact load / compile / execute).
    Runtime(String),
    /// The serving coordinator was shut down or a worker died.
    Coordinator(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Config(m) => write!(f, "config error: {m}"),
            Error::Shape(m) => write!(f, "shape error: {m}"),
            Error::Serialize(m) => write!(f, "serialize error: {m}"),
            Error::Io(e) => write!(f, "io error: {e}"),
            Error::Runtime(m) => write!(f, "runtime error: {m}"),
            Error::Coordinator(m) => write!(f, "coordinator error: {m}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

/// Library result alias.
pub type Result<T> = std::result::Result<T, Error>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_covers_variants() {
        let cases: Vec<(Error, &str)> = vec![
            (Error::Config("x".into()), "config"),
            (Error::Shape("x".into()), "shape"),
            (Error::Serialize("x".into()), "serialize"),
            (
                Error::Io(std::io::Error::new(std::io::ErrorKind::Other, "x")),
                "io",
            ),
            (Error::Runtime("x".into()), "runtime"),
            (Error::Coordinator("x".into()), "coordinator"),
        ];
        for (e, frag) in cases {
            assert!(e.to_string().contains(frag), "{e}");
        }
    }

    #[test]
    fn io_error_converts() {
        fn failing() -> Result<()> {
            Err(std::io::Error::new(std::io::ErrorKind::NotFound, "gone"))?;
            Ok(())
        }
        assert!(matches!(failing(), Err(Error::Io(_))));
    }
}
