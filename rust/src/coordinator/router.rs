//! Shard router: the legacy data-parallel fan-out API, now a thin frozen
//! view over [`Collection`].
//!
//! At billion scale the paper's index is served from multiple replicas /
//! shards (Appendix A.4 discusses replica counts). The original
//! `ShardedIndex` was a static fan-out that rebuilt a `Searcher` per
//! query and could not be mutated, served, or serialized; it is now a
//! facade over the unified `Collection` stack, so the same shards are
//! independently mutable (unfreeze via [`ShardedIndex::into_collection`]),
//! servable (`ServeEngine::start_collection`), and serializable (v3
//! manifests) — while this type keeps the frozen build-then-query shape
//! for read-only workloads.

use std::sync::Arc;

use crate::config::{CollectionConfig, IndexConfig, MutableConfig, SearchParams, ShardRouting};
use crate::error::Result;
use crate::index::searcher::SearchStats;
use crate::index::Collection;
use crate::linalg::topk::Scored;
use crate::linalg::MatrixF32;
use crate::runtime::Engine;

/// A corpus split across shards, each with its own index — frozen at
/// build time. Ids returned by [`ShardedIndex::search`] are global row
/// indexes of the build corpus.
pub struct ShardedIndex {
    collection: Collection,
}

impl ShardedIndex {
    /// Route `data`'s rows across `num_shards` shards by id hash and
    /// build one index per shard (in parallel). Partition counts scale
    /// with each shard's share of the corpus; one int8 quantizer spans
    /// all shards so merged scores are exactly comparable.
    pub fn build(
        engine: Arc<Engine>,
        data: &MatrixF32,
        config: &IndexConfig,
        num_shards: usize,
    ) -> Result<ShardedIndex> {
        // Default mutation policy: the frozen view never mutates, and if
        // the caller unfreezes via `into_collection` the shards keep the
        // normal inline auto-compaction triggers.
        let ccfg = CollectionConfig {
            num_shards,
            routing: ShardRouting::Hash,
            mutable: MutableConfig::default(),
            background_compact: false,
            maintenance: Default::default(),
            durability: Default::default(),
        };
        Ok(ShardedIndex {
            collection: Collection::build(engine, data, config, ccfg)?,
        })
    }

    pub fn num_shards(&self) -> usize {
        self.collection.num_shards()
    }

    pub fn total_points(&self) -> usize {
        self.collection.snapshot().live_count()
    }

    /// Fan out to all shards in parallel and merge by score. Returned ids
    /// are global row indexes.
    pub fn search(&self, q: &[f32], params: &SearchParams) -> (Vec<Scored>, SearchStats) {
        self.collection.search(q, params)
    }

    /// The backing collection (read access: snapshots, cells, stats).
    pub fn collection(&self) -> &Collection {
        &self.collection
    }

    /// Unfreeze: hand the shards over as a mutable, servable
    /// [`Collection`].
    pub fn into_collection(self) -> Collection {
        self.collection
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SpillMode;
    use crate::data::ground_truth::ground_truth_mips;
    use crate::data::synthetic::SyntheticConfig;
    use crate::index::{build_index, SearchScratch, Searcher};

    #[test]
    fn sharded_covers_all_points() {
        let ds = SyntheticConfig::glove_like(900, 16, 8, 55).generate();
        let engine = Arc::new(Engine::cpu());
        let cfg = IndexConfig {
            num_partitions: 18,
            spill: SpillMode::Soar { lambda: 1.0 },
            ..Default::default()
        };
        let sharded = ShardedIndex::build(engine, &ds.data, &cfg, 3).unwrap();
        assert_eq!(sharded.num_shards(), 3);
        assert_eq!(sharded.total_points(), 900);
        // Every row landed on exactly one shard, where its id routes.
        let snap = sharded.collection().snapshot();
        let mut seen = 0usize;
        for (s, shard) in snap.shards.iter().enumerate() {
            for seg in &shard.sealed {
                for &g in &seg.global_ids {
                    assert_eq!(sharded.collection().shard_of(g), s);
                    seen += 1;
                }
            }
        }
        assert_eq!(seen, 900);
    }

    #[test]
    fn sharded_search_matches_ground_truth_at_full_probe() {
        let ds = SyntheticConfig::glove_like(1200, 16, 10, 56).generate();
        let engine = Arc::new(Engine::cpu());
        let cfg = IndexConfig {
            num_partitions: 24,
            spill: SpillMode::Soar { lambda: 1.0 },
            ..Default::default()
        };
        let sharded = ShardedIndex::build(engine, &ds.data, &cfg, 4).unwrap();
        let gt = ground_truth_mips(&ds.data, &ds.queries, 10);
        let params = SearchParams {
            k: 10,
            top_t: 1000, // probe everything in each shard
            rerank_budget: 300,
        };
        let mut results = Vec::new();
        for qi in 0..ds.num_queries() {
            let (res, stats) = sharded.search(ds.queries.row(qi), &params);
            assert!(res.len() <= 10);
            // every shard contributed to the scan
            assert!(stats.segments_scanned >= 4);
            for r in &res {
                assert!((r.id as usize) < 1200, "global id in range");
            }
            results.push(res.into_iter().map(|s| s.id).collect::<Vec<_>>());
        }
        let recall = gt.mean_recall(&results);
        assert!(recall > 0.85, "sharded full-probe recall {recall}");
    }

    #[test]
    fn single_shard_equivalent_to_unsharded() {
        let ds = SyntheticConfig::glove_like(500, 16, 5, 57).generate();
        let engine = Arc::new(Engine::cpu());
        let cfg = IndexConfig {
            num_partitions: 10,
            spill: SpillMode::None,
            ..Default::default()
        };
        let sharded = ShardedIndex::build(engine.clone(), &ds.data, &cfg, 1).unwrap();
        let direct = build_index(&engine, &ds.data, &cfg).unwrap();
        let params = SearchParams::default();
        let mut scratch = SearchScratch::new(&direct);
        for qi in 0..5 {
            let (a, _) = sharded.search(ds.queries.row(qi), &params);
            let searcher = Searcher::new(&direct, &engine);
            let (b, _) = searcher.search(ds.queries.row(qi), &params, &mut scratch);
            assert_eq!(a, b, "1-shard results must be identical, scores included");
        }
        // Unfreezing keeps the data and makes it mutable.
        let collection = sharded.into_collection();
        collection.upsert(600, ds.data.row(0)).unwrap();
        assert_eq!(collection.snapshot().live_count(), 501);
    }
}
