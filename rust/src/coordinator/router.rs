//! Shard router: data-parallel sharding with fan-out/merge search.
//!
//! At billion scale the paper's index is served from multiple replicas /
//! shards (Appendix A.4 discusses replica counts); this router implements
//! the standard data-parallel layout: the corpus is split across S shards,
//! each holding its own SOAR index over its slice; a query fans out to
//! every shard and the per-shard top-k lists are merged by score.

use crate::config::{IndexConfig, SearchParams};
use crate::error::Result;
use crate::index::{build_index, SearchScratch, Searcher, SoarIndex};
use crate::linalg::topk::{Scored, TopK};
use crate::linalg::MatrixF32;
use crate::runtime::Engine;
use crate::util::parallel::par_map;

/// A corpus split across shards, each with its own index.
pub struct ShardedIndex {
    pub shards: Vec<SoarIndex>,
    /// Global id of shard s's local id 0.
    pub offsets: Vec<u32>,
}

impl ShardedIndex {
    /// Split `data` into `num_shards` contiguous slices and build one
    /// index per shard (in parallel).
    pub fn build(
        engine: &Engine,
        data: &MatrixF32,
        config: &IndexConfig,
        num_shards: usize,
    ) -> Result<ShardedIndex> {
        assert!(num_shards >= 1);
        let n = data.rows();
        let per = n.div_ceil(num_shards);
        let mut slices = Vec::new();
        let mut offsets = Vec::new();
        let mut start = 0usize;
        while start < n {
            let stop = (start + per).min(n);
            offsets.push(start as u32);
            slices.push((start, stop));
            start = stop;
        }
        // Partition count scales with shard size to keep pts/partition.
        let shards: Result<Vec<SoarIndex>> = par_map(slices.len(), |si| {
            let (lo, hi) = slices[si];
            let rows: Vec<usize> = (lo..hi).collect();
            let slice = data.gather_rows(&rows);
            let mut cfg = config.clone();
            cfg.num_partitions = ((hi - lo) * config.num_partitions / n).max(2);
            build_index(engine, &slice, &cfg)
        })
        .into_iter()
        .collect();
        Ok(ShardedIndex {
            shards: shards?,
            offsets,
        })
    }

    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    pub fn total_points(&self) -> usize {
        self.shards.iter().map(|s| s.n).sum()
    }

    /// Fan out to all shards and merge by score. Returned ids are
    /// *global* (shard offset applied).
    pub fn search(
        &self,
        engine: &Engine,
        q: &[f32],
        params: &SearchParams,
        scratches: &mut [SearchScratch],
    ) -> Vec<Scored> {
        assert_eq!(scratches.len(), self.shards.len());
        let mut merged = TopK::new(params.k);
        for (s, (shard, scratch)) in
            self.shards.iter().zip(scratches.iter_mut()).enumerate()
        {
            let searcher = Searcher::new(shard, engine);
            let (results, _) = searcher.search(q, params, scratch);
            let off = self.offsets[s];
            for r in results {
                merged.push(r.id + off, r.score);
            }
        }
        merged.into_sorted()
    }

    /// Fresh per-shard scratch set.
    pub fn make_scratches(&self) -> Vec<SearchScratch> {
        self.shards.iter().map(SearchScratch::new).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SpillMode;
    use crate::data::ground_truth::ground_truth_mips;
    use crate::data::synthetic::SyntheticConfig;

    #[test]
    fn sharded_covers_all_points() {
        let ds = SyntheticConfig::glove_like(900, 16, 8, 55).generate();
        let engine = Engine::cpu();
        let cfg = IndexConfig {
            num_partitions: 18,
            spill: SpillMode::Soar { lambda: 1.0 },
            ..Default::default()
        };
        let sharded = ShardedIndex::build(&engine, &ds.data, &cfg, 3).unwrap();
        assert_eq!(sharded.num_shards(), 3);
        assert_eq!(sharded.total_points(), 900);
        assert_eq!(sharded.offsets, vec![0, 300, 600]);
    }

    #[test]
    fn sharded_search_matches_ground_truth_at_full_probe() {
        let ds = SyntheticConfig::glove_like(1200, 16, 10, 56).generate();
        let engine = Engine::cpu();
        let cfg = IndexConfig {
            num_partitions: 24,
            spill: SpillMode::Soar { lambda: 1.0 },
            ..Default::default()
        };
        let sharded = ShardedIndex::build(&engine, &ds.data, &cfg, 4).unwrap();
        let gt = ground_truth_mips(&ds.data, &ds.queries, 10);
        let params = SearchParams {
            k: 10,
            top_t: 1000, // probe everything in each shard
            rerank_budget: 300,
        };
        let mut scratches = sharded.make_scratches();
        let mut results = Vec::new();
        for qi in 0..ds.num_queries() {
            let res = sharded.search(&engine, ds.queries.row(qi), &params, &mut scratches);
            assert!(res.len() <= 10);
            // global ids must be in range
            for r in &res {
                assert!((r.id as usize) < 1200);
            }
            results.push(res.into_iter().map(|s| s.id).collect::<Vec<_>>());
        }
        let recall = gt.mean_recall(&results);
        assert!(recall > 0.85, "sharded full-probe recall {recall}");
    }

    #[test]
    fn single_shard_equivalent_to_unsharded() {
        let ds = SyntheticConfig::glove_like(500, 16, 5, 57).generate();
        let engine = Engine::cpu();
        let cfg = IndexConfig {
            num_partitions: 10,
            spill: SpillMode::None,
            ..Default::default()
        };
        let sharded = ShardedIndex::build(&engine, &ds.data, &cfg, 1).unwrap();
        let direct = build_index(&engine, &ds.data, &cfg).unwrap();
        let params = SearchParams::default();
        let mut scratches = sharded.make_scratches();
        let mut scratch = SearchScratch::new(&direct);
        for qi in 0..5 {
            let a = sharded.search(&engine, ds.queries.row(qi), &params, &mut scratches);
            let searcher = Searcher::new(&direct, &engine);
            let (b, _) = searcher.search(ds.queries.row(qi), &params, &mut scratch);
            let ids_a: Vec<u32> = a.iter().map(|s| s.id).collect();
            let ids_b: Vec<u32> = b.iter().map(|s| s.id).collect();
            assert_eq!(ids_a, ids_b);
        }
    }
}
