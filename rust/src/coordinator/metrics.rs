//! Serving metrics: lock-free counters + a log-bucketed latency histogram.
//!
//! The Fig 11/12 benchmarks report recall-vs-QPS and tail latency; this is
//! the instrumentation that produces those numbers from the live serving
//! stack.

use crate::util::sync::atomic::{AtomicU64, Ordering};
use crate::util::sync::Mutex;
use std::time::Instant;

/// Log₂-bucketed latency histogram over microseconds.
///
/// 64 buckets: bucket i holds samples with `floor(log2(us)) == i`
/// (bucket 0 also catches 0µs). Quantiles are estimated at bucket
/// midpoints — ±50% resolution, plenty for p50/p99 reporting.
#[derive(Debug)]
pub struct LatencyHistogram {
    buckets: [u64; 64],
    count: u64,
    sum_us: u64,
    max_us: u64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        LatencyHistogram {
            buckets: [0; 64],
            count: 0,
            sum_us: 0,
            max_us: 0,
        }
    }
}

impl LatencyHistogram {
    pub fn record(&mut self, us: u64) {
        let b = if us == 0 { 0 } else { 63 - us.leading_zeros() as usize };
        self.buckets[b.min(63)] += 1;
        self.count += 1;
        self.sum_us += us;
        self.max_us = self.max_us.max(us);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean_us(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_us as f64 / self.count as f64
        }
    }

    pub fn max_us(&self) -> u64 {
        self.max_us
    }

    /// Quantile estimate (bucket midpoint), q in [0, 1].
    pub fn quantile_us(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let target = ((q.clamp(0.0, 1.0)) * self.count as f64).ceil().max(1.0) as u64;
        let mut acc = 0u64;
        for (b, &c) in self.buckets.iter().enumerate() {
            acc += c;
            if acc >= target {
                // midpoint of [2^b, 2^(b+1))
                return (1u64 << b) + (1u64 << b) / 2;
            }
        }
        self.max_us
    }

    pub fn merge(&mut self, other: &LatencyHistogram) {
        for (a, b) in self.buckets.iter_mut().zip(&other.buckets) {
            *a += b;
        }
        self.count += other.count;
        self.sum_us += other.sum_us;
        self.max_us = self.max_us.max(other.max_us);
    }
}

/// Shared serving metrics.
#[derive(Debug)]
pub struct ServeMetrics {
    queries: AtomicU64,
    batches: AtomicU64,
    batch_size_sum: AtomicU64,
    rejected: AtomicU64,
    /// Batches dispatched without paying the batching window (a worker
    /// was idle — adaptive admission).
    immediate_batches: AtomicU64,
    /// Batches that accumulated under the `max_wait_us` deadline (all
    /// workers were busy).
    waited_batches: AtomicU64,
    /// Non-empty posting-list scans, summed over served queries
    /// ([`SearchStats::lists_scanned`](crate::index::SearchStats)).
    lists_scanned: AtomicU64,
    /// Physical code bytes streamed, summed over served queries. Grouped
    /// batched execution charges each streamed list once per scan group,
    /// so `code_bytes_streamed / queries` falls as batches deepen — the
    /// cross-query amortization the segment-major executor exists for.
    code_bytes_streamed: AtomicU64,
    latency: Mutex<LatencyHistogram>,
    started: Instant,
}

impl Default for ServeMetrics {
    fn default() -> Self {
        ServeMetrics {
            queries: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            batch_size_sum: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
            immediate_batches: AtomicU64::new(0),
            waited_batches: AtomicU64::new(0),
            lists_scanned: AtomicU64::new(0),
            code_bytes_streamed: AtomicU64::new(0),
            latency: Mutex::new(LatencyHistogram::default()),
            started: Instant::now(),
        }
    }
}

impl ServeMetrics {
    pub fn record_batch(&self, batch_size: usize, per_query_latency_us: &[u64]) {
        self.queries.fetch_add(batch_size as u64, Ordering::Relaxed);
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batch_size_sum
            .fetch_add(batch_size as u64, Ordering::Relaxed);
        let mut h = self.latency.lock().unwrap();
        for &us in per_query_latency_us {
            h.record(us);
        }
    }

    /// Fold one batch's aggregate scan work (summed over its queries)
    /// into the serving counters.
    pub fn record_scan_work(&self, lists_scanned: u64, code_bytes_streamed: u64) {
        self.lists_scanned.fetch_add(lists_scanned, Ordering::Relaxed);
        self.code_bytes_streamed
            .fetch_add(code_bytes_streamed, Ordering::Relaxed);
    }

    pub fn record_rejected(&self) {
        self.rejected.fetch_add(1, Ordering::Relaxed);
    }

    /// Record which admission path a batch took at dispatch time:
    /// `waited == false` means an idle worker let it skip the batching
    /// window entirely.
    pub fn record_admission(&self, waited: bool) {
        if waited {
            self.waited_batches.fetch_add(1, Ordering::Relaxed);
        } else {
            self.immediate_batches.fetch_add(1, Ordering::Relaxed);
        }
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let h = self.latency.lock().unwrap();
        let queries = self.queries.load(Ordering::Relaxed);
        let batches = self.batches.load(Ordering::Relaxed);
        let elapsed = self.started.elapsed().as_secs_f64();
        MetricsSnapshot {
            queries,
            batches,
            rejected: self.rejected.load(Ordering::Relaxed),
            mean_batch: if batches == 0 {
                0.0
            } else {
                self.batch_size_sum.load(Ordering::Relaxed) as f64 / batches as f64
            },
            immediate_batches: self.immediate_batches.load(Ordering::Relaxed),
            waited_batches: self.waited_batches.load(Ordering::Relaxed),
            lists_scanned: self.lists_scanned.load(Ordering::Relaxed),
            code_bytes_streamed: self.code_bytes_streamed.load(Ordering::Relaxed),
            qps: if elapsed > 0.0 {
                queries as f64 / elapsed
            } else {
                0.0
            },
            mean_us: h.mean_us(),
            p50_us: h.quantile_us(0.5),
            p99_us: h.quantile_us(0.99),
            max_us: h.max_us(),
        }
    }
}

/// Point-in-time metrics view.
#[derive(Clone, Copy, Debug)]
pub struct MetricsSnapshot {
    pub queries: u64,
    pub batches: u64,
    pub rejected: u64,
    pub immediate_batches: u64,
    pub waited_batches: u64,
    /// Summed [`SearchStats::lists_scanned`](crate::index::SearchStats)
    /// across served queries.
    pub lists_scanned: u64,
    /// Summed `SearchStats::code_bytes_streamed` across served queries;
    /// divide by `queries` to see the grouped executor's per-query
    /// bandwidth amortization.
    pub code_bytes_streamed: u64,
    pub mean_batch: f64,
    pub qps: f64,
    pub mean_us: f64,
    pub p50_us: u64,
    pub p99_us: u64,
    pub max_us: u64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_quantiles_rough() {
        let mut h = LatencyHistogram::default();
        for us in 1..=1000u64 {
            h.record(us);
        }
        assert_eq!(h.count(), 1000);
        let p50 = h.quantile_us(0.5);
        // true p50 = 500; bucket resolution gives [256, 768]
        assert!((256..=768).contains(&p50), "p50={p50}");
        let p99 = h.quantile_us(0.99);
        assert!(p99 >= 512, "p99={p99}");
        assert!((h.mean_us() - 500.5).abs() < 1.0);
        assert_eq!(h.max_us(), 1000);
    }

    #[test]
    fn histogram_zero_and_empty() {
        let mut h = LatencyHistogram::default();
        assert_eq!(h.quantile_us(0.5), 0);
        h.record(0);
        assert_eq!(h.count(), 1);
        assert!(h.quantile_us(0.5) <= 2);
    }

    #[test]
    fn merge_adds() {
        let mut a = LatencyHistogram::default();
        let mut b = LatencyHistogram::default();
        a.record(10);
        b.record(1000);
        a.merge(&b);
        assert_eq!(a.count(), 2);
        assert_eq!(a.max_us(), 1000);
    }

    #[test]
    fn serve_metrics_snapshot() {
        let m = ServeMetrics::default();
        m.record_batch(3, &[100, 200, 300]);
        m.record_batch(1, &[50]);
        m.record_rejected();
        m.record_admission(true);
        m.record_admission(false);
        m.record_scan_work(12, 4096);
        m.record_scan_work(3, 512);
        let s = m.snapshot();
        assert_eq!(s.queries, 4);
        assert_eq!(s.batches, 2);
        assert_eq!(s.rejected, 1);
        assert_eq!(s.immediate_batches, 1);
        assert_eq!(s.waited_batches, 1);
        assert_eq!(s.lists_scanned, 15);
        assert_eq!(s.code_bytes_streamed, 4608);
        assert!((s.mean_batch - 2.0).abs() < 1e-9);
        assert!(s.mean_us > 0.0);
        assert!(s.qps > 0.0);
    }
}
