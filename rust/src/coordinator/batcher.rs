//! Dynamic batching: fuse single-query requests into scoring batches.
//!
//! A deeper batch is cheaper per query twice over: the centroid-scoring
//! stage is a matmul whose dispatch cost (PJRT; AOT buckets compiled at
//! B=64) and GEMM blocking amortize across the batch, and the grouped
//! segment-major executor downstream streams each probed posting list
//! **once per batch scan group** instead of once per query — so
//! `code_bytes_streamed / queries` falls as batches deepen. The batcher
//! trades a bounded queueing delay (`max_wait_us`) for that
//! amortization, exactly like vLLM's request batcher. Policy:
//!
//! * a batch is flushed when it reaches `max_batch`, or
//! * when the *first* request in it has waited `max_wait_us` since the
//!   batch opened.
//!
//! The serving stack layers *adaptive admission* on top
//! ([`collect_batch_adaptive`]): when a worker is idle there is nothing
//! to amortize against, so the batch is dispatched immediately (taking
//! any already-queued backlog without waiting); the `max_wait_us` delay
//! is only paid when every worker is busy and waiting actually buys
//! amortization. Low-load latency is thus the search cost itself, not
//! search + the batching window.
//!
//! Built on `std::sync::mpsc` (this repo's offline vendor set has no
//! async runtime); the serving stack in `server.rs` runs the loop on a
//! dedicated thread.

use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::time::{Duration, Instant};

use crate::linalg::topk::Scored;

/// Single-use response channel (oneshot stand-in).
pub type ResponseTx = std::sync::mpsc::SyncSender<Vec<Scored>>;

/// One in-flight query.
#[derive(Debug)]
pub struct QueryRequest {
    pub query: Vec<f32>,
    /// Overrides the engine-default k when `Some`.
    pub k: Option<usize>,
    pub enqueued: Instant,
    pub respond: ResponseTx,
}

/// Collect the next batch from `rx`.
///
/// Blocks for the first request indefinitely (returns `None` when the
/// channel is closed and drained — shutdown), then gathers more until
/// `max_batch` or the deadline.
pub fn collect_batch(
    rx: &Receiver<QueryRequest>,
    max_batch: usize,
    max_wait: Duration,
) -> Option<Vec<QueryRequest>> {
    let first = rx.recv().ok()?;
    Some(collect_batch_with_first(first, rx, max_batch, max_wait))
}

/// Assemble a batch around an already-received first request. Used by the
/// server's intake loop, which polls with a timeout so it can observe a
/// shutdown flag (a bare `recv()` would block forever while client handles
/// keep the channel open).
pub fn collect_batch_with_first(
    first: QueryRequest,
    rx: &Receiver<QueryRequest>,
    max_batch: usize,
    max_wait: Duration,
) -> Vec<QueryRequest> {
    let deadline = Instant::now() + max_wait;
    let mut batch = vec![first];
    while batch.len() < max_batch {
        let now = Instant::now();
        if now >= deadline {
            break;
        }
        match rx.recv_timeout(deadline - now) {
            Ok(req) => batch.push(req),
            Err(RecvTimeoutError::Timeout) => break,
            Err(RecvTimeoutError::Disconnected) => break, // flush remainder
        }
    }
    batch
}

/// Adaptive admission: assemble a batch around `first`, waiting only
/// when it pays.
///
/// * `busy == false` (an idle worker exists): dispatch now — take the
///   already-queued backlog via `try_recv` up to `max_batch`, but never
///   wait. Queueing delay would be pure latency with no amortization
///   gain.
/// * `busy == true` (all workers occupied): fall back to the deadline
///   policy of [`collect_batch_with_first`] — the batch cannot start
///   sooner than a worker frees up anyway, so the wait is (partially)
///   hidden behind the in-flight batch.
pub fn collect_batch_adaptive(
    first: QueryRequest,
    rx: &Receiver<QueryRequest>,
    max_batch: usize,
    max_wait: Duration,
    busy: bool,
) -> Vec<QueryRequest> {
    if busy {
        return collect_batch_with_first(first, rx, max_batch, max_wait);
    }
    let mut batch = vec![first];
    while batch.len() < max_batch {
        match rx.try_recv() {
            Ok(req) => batch.push(req),
            Err(_) => break,
        }
    }
    batch
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc;

    fn req(v: f32) -> (QueryRequest, std::sync::mpsc::Receiver<Vec<Scored>>) {
        let (tx, rx) = mpsc::sync_channel(1);
        (
            QueryRequest {
                query: vec![v],
                k: None,
                enqueued: Instant::now(),
                respond: tx,
            },
            rx,
        )
    }

    #[test]
    fn flushes_at_max_batch() {
        let (tx, rx) = mpsc::channel();
        let mut keeps = Vec::new();
        for i in 0..5 {
            let (r, keep) = req(i as f32);
            keeps.push(keep);
            tx.send(r).unwrap();
        }
        let batch = collect_batch(&rx, 3, Duration::from_secs(10)).unwrap();
        assert_eq!(batch.len(), 3);
        let batch = collect_batch(&rx, 3, Duration::from_millis(10)).unwrap();
        assert_eq!(batch.len(), 2);
    }

    #[test]
    fn flushes_at_deadline() {
        let (tx, rx) = mpsc::channel();
        let (r, _keep) = req(1.0);
        tx.send(r).unwrap();
        let start = Instant::now();
        let batch = collect_batch(&rx, 64, Duration::from_millis(20)).unwrap();
        assert_eq!(batch.len(), 1);
        let waited = start.elapsed();
        assert!(waited >= Duration::from_millis(15), "waited {waited:?}");
        assert!(waited < Duration::from_secs(2), "waited {waited:?}");
    }

    #[test]
    fn returns_none_on_shutdown() {
        let (tx, rx) = mpsc::channel::<QueryRequest>();
        drop(tx);
        assert!(collect_batch(&rx, 4, Duration::from_millis(1)).is_none());
    }

    #[test]
    fn batch_preserves_arrival_order() {
        let (tx, rx) = mpsc::channel();
        let mut keeps = Vec::new();
        for i in 0..4 {
            let (r, keep) = req(i as f32);
            keeps.push(keep);
            tx.send(r).unwrap();
        }
        let batch = collect_batch(&rx, 8, Duration::from_millis(5)).unwrap();
        let vals: Vec<f32> = batch.iter().map(|r| r.query[0]).collect();
        assert_eq!(vals, vec![0.0, 1.0, 2.0, 3.0]);
    }

    #[test]
    fn idle_dispatch_skips_the_wait() {
        let (tx, rx) = mpsc::channel();
        let (r, _keep) = req(1.0);
        tx.send(r).unwrap();
        let first = rx.recv().unwrap();
        let start = Instant::now();
        let batch = collect_batch_adaptive(first, &rx, 64, Duration::from_secs(5), false);
        assert_eq!(batch.len(), 1);
        assert!(
            start.elapsed() < Duration::from_secs(1),
            "idle dispatch must not pay the batching window"
        );
    }

    #[test]
    fn idle_dispatch_drains_queued_backlog() {
        let (tx, rx) = mpsc::channel();
        let mut keeps = Vec::new();
        for i in 0..5 {
            let (r, keep) = req(i as f32);
            keeps.push(keep);
            tx.send(r).unwrap();
        }
        let first = rx.recv().unwrap();
        let batch = collect_batch_adaptive(first, &rx, 3, Duration::from_secs(5), false);
        // Backlog joins up to max_batch even on the no-wait path.
        assert_eq!(batch.len(), 3);
        let vals: Vec<f32> = batch.iter().map(|r| r.query[0]).collect();
        assert_eq!(vals, vec![0.0, 1.0, 2.0]);
    }

    #[test]
    fn busy_dispatch_accumulates_until_deadline() {
        let (tx, rx) = mpsc::channel();
        let (r, _keep) = req(1.0);
        tx.send(r).unwrap();
        let first = rx.recv().unwrap();
        let start = Instant::now();
        let batch = collect_batch_adaptive(first, &rx, 64, Duration::from_millis(20), true);
        assert_eq!(batch.len(), 1);
        assert!(
            start.elapsed() >= Duration::from_millis(15),
            "busy dispatch keeps the deadline policy"
        );
    }

    #[test]
    fn late_arrivals_join_open_batch() {
        let (tx, rx) = mpsc::channel();
        let (r, _keep) = req(0.0);
        tx.send(r).unwrap();
        let sender = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(5));
            let (r, keep) = req(1.0);
            std::mem::forget(keep);
            tx.send(r).unwrap();
        });
        let batch = collect_batch(&rx, 8, Duration::from_millis(200)).unwrap();
        sender.join().unwrap();
        assert_eq!(batch.len(), 2);
    }
}
