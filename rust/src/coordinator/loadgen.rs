//! Open-loop (Poisson-arrival) load generation.
//!
//! Closed-loop clients (`server::closed_loop_load`) measure peak
//! throughput but hide queueing delay: clients slow down when the system
//! does. Serving systems are evaluated under *open-loop* load — requests
//! arrive at a fixed offered rate regardless of completion — which is what
//! exposes the latency-vs-load curve behind the paper's QPS-at-recall
//! operating points.

use crate::util::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::coordinator::metrics::LatencyHistogram;
use crate::coordinator::server::ServeHandle;
use crate::linalg::{MatrixF32, Rng};

/// Result of one open-loop run.
#[derive(Clone, Debug)]
pub struct OpenLoopReport {
    pub offered_qps: f64,
    pub achieved_qps: f64,
    pub completed: u64,
    /// Requests rejected by backpressure (dropped, not retried).
    pub rejected: u64,
    pub p50_us: u64,
    pub p99_us: u64,
    pub mean_us: f64,
}

/// Drive `handle` with Poisson arrivals at `offered_qps` for `duration`.
///
/// `concurrency` dispatcher threads share the arrival schedule; each
/// dispatched request blocks one thread until completion, so choose
/// `concurrency` comfortably above `offered_qps × expected latency`.
pub fn open_loop_load(
    handle: &ServeHandle,
    queries: &MatrixF32,
    offered_qps: f64,
    duration: Duration,
    concurrency: usize,
    seed: u64,
) -> OpenLoopReport {
    assert!(offered_qps > 0.0);
    let completed = AtomicU64::new(0);
    let rejected = AtomicU64::new(0);
    let issued = AtomicU64::new(0);
    let hist = crate::util::sync::Mutex::new(LatencyHistogram::default());
    let start = Instant::now();
    let deadline = start + duration;

    // Pre-draw the Poisson schedule (absolute send times).
    let mut rng = Rng::new(seed);
    let mut schedule = Vec::new();
    let mut t = 0.0f64;
    while t < duration.as_secs_f64() {
        // exponential inter-arrival
        let u = (1.0 - rng.next_f32() as f64).max(1e-12);
        t += -u.ln() / offered_qps;
        schedule.push(start + Duration::from_secs_f64(t));
    }
    let schedule = Arc::new(schedule);
    let next_idx = AtomicU64::new(0);

    std::thread::scope(|s| {
        for _ in 0..concurrency.max(1) {
            let handle = handle.clone();
            let schedule = schedule.clone();
            let next_idx = &next_idx;
            let completed = &completed;
            let rejected = &rejected;
            let issued = &issued;
            let hist = &hist;
            s.spawn(move || loop {
                let i = next_idx.fetch_add(1, Ordering::Relaxed) as usize;
                if i >= schedule.len() {
                    break;
                }
                let send_at = schedule[i];
                if send_at > deadline {
                    break;
                }
                let now = Instant::now();
                if send_at > now {
                    std::thread::sleep(send_at - now);
                }
                let qi = i % queries.rows();
                issued.fetch_add(1, Ordering::Relaxed);
                let t0 = Instant::now();
                match handle.search(queries.row(qi).to_vec()) {
                    Ok(_) => {
                        completed.fetch_add(1, Ordering::Relaxed);
                        hist.lock()
                            .unwrap()
                            .record(t0.elapsed().as_micros() as u64);
                    }
                    Err(_) => {
                        // Open loop: drop on backpressure, do not retry.
                        rejected.fetch_add(1, Ordering::Relaxed);
                    }
                }
            });
        }
    });

    let elapsed = start.elapsed().as_secs_f64();
    let h = hist.into_inner().unwrap();
    OpenLoopReport {
        offered_qps,
        achieved_qps: completed.load(Ordering::Relaxed) as f64 / elapsed.max(1e-9),
        completed: completed.load(Ordering::Relaxed),
        rejected: rejected.load(Ordering::Relaxed),
        p50_us: h.quantile_us(0.5),
        p99_us: h.quantile_us(0.99),
        mean_us: h.mean_us(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{IndexConfig, SearchParams, ServeConfig, SpillMode};
    use crate::coordinator::server::ServeEngine;
    use crate::data::synthetic::SyntheticConfig;
    use crate::index::build_index;
    use crate::runtime::Engine;

    #[test]
    fn open_loop_under_capacity_completes_everything() {
        let ds = SyntheticConfig::glove_like(2000, 16, 16, 3).generate();
        let engine = Arc::new(Engine::cpu());
        let cfg = IndexConfig::for_dataset(ds.n(), SpillMode::Soar { lambda: 1.0 });
        let index = Arc::new(build_index(&engine, &ds.data, &cfg).unwrap());
        let server = ServeEngine::start(
            index,
            engine,
            SearchParams::default(),
            ServeConfig::default(),
        );
        let handle = server.handle();
        let report = open_loop_load(
            &handle,
            &ds.queries,
            200.0, // far under capacity for a 2k index
            Duration::from_millis(400),
            8,
            1,
        );
        assert!(report.completed > 20, "completed {}", report.completed);
        assert_eq!(report.rejected, 0);
        assert!(report.achieved_qps > 50.0, "{}", report.achieved_qps);
        assert!(report.p99_us > 0);
        server.shutdown();
    }

    #[test]
    fn poisson_schedule_is_roughly_offered_rate() {
        // Statistical sanity on the arrival process itself.
        let mut rng = Rng::new(9);
        let rate = 1000.0f64;
        let horizon = 2.0f64;
        let mut t = 0.0;
        let mut count = 0usize;
        while t < horizon {
            let u = (1.0 - rng.next_f32() as f64).max(1e-12);
            t += -u.ln() / rate;
            count += 1;
        }
        let expected = rate * horizon;
        assert!(
            (count as f64 - expected).abs() < 0.15 * expected,
            "count {count} vs expected {expected}"
        );
    }
}
