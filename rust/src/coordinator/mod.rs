//! L3 serving coordinator: router → dynamic batcher → search workers.
//!
//! The paper's system is a serving engine (ScaNN / big-ann-benchmarks
//! Track 3); this module provides the vLLM-router-shaped runtime around
//! the index: a tokio stack that accepts single-query requests, fuses them
//! into scoring batches (amortizing the PJRT centroid-scoring call),
//! fans out across index shards, deduplicates spilled candidates, and
//! reports latency/throughput metrics.

pub mod batcher;
pub mod dedup;
pub mod loadgen;
pub mod metrics;
pub mod router;
pub mod server;

pub use dedup::DedupSet;
pub use metrics::{LatencyHistogram, MetricsSnapshot, ServeMetrics};
pub use loadgen::{open_loop_load, OpenLoopReport};
pub use server::{ServeEngine, ServeHandle};
