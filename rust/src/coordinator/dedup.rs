//! Epoch-stamped visited set for spilled-candidate deduplication.
//!
//! With spilling, a datapoint can appear in several probed partitions;
//! §3.5 notes the search must deduplicate. A per-query `HashSet` would
//! allocate on the hot path; instead we keep one `u32` stamp per datapoint
//! and bump an epoch counter per query — `reset()` is O(1) and `insert()`
//! is a single indexed load/store.

/// O(1)-reset visited set over ids `0..capacity`.
#[derive(Clone, Debug)]
pub struct DedupSet {
    stamps: Vec<u32>,
    epoch: u32,
}

impl DedupSet {
    /// Set over ids `0..capacity`.
    pub fn new(capacity: usize) -> DedupSet {
        DedupSet {
            stamps: vec![0; capacity],
            epoch: 1,
        }
    }

    pub fn capacity(&self) -> usize {
        self.stamps.len()
    }

    /// Grow to cover at least `capacity` ids (existing marks preserved).
    pub fn ensure_capacity(&mut self, capacity: usize) {
        if capacity > self.stamps.len() {
            self.stamps.resize(capacity, 0);
        }
    }

    /// Forget all marks. O(1) except once every 2³²−1 resets.
    #[inline]
    pub fn reset(&mut self) {
        self.epoch = self.epoch.wrapping_add(1);
        if self.epoch == 0 {
            // Wrapped: stale stamps could collide; do the rare full clear.
            self.stamps.fill(0);
            self.epoch = 1;
        }
    }

    /// Mark `id`; returns `true` iff it was not already marked.
    #[inline]
    pub fn insert(&mut self, id: u32) -> bool {
        let slot = &mut self.stamps[id as usize];
        if *slot == self.epoch {
            false
        } else {
            *slot = self.epoch;
            true
        }
    }

    /// Is `id` marked in the current epoch?
    #[inline]
    pub fn contains(&self, id: u32) -> bool {
        self.stamps[id as usize] == self.epoch
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn insert_semantics() {
        let mut s = DedupSet::new(10);
        assert!(s.insert(3));
        assert!(!s.insert(3));
        assert!(s.contains(3));
        assert!(!s.contains(4));
        assert!(s.insert(4));
    }

    #[test]
    fn reset_clears_in_o1() {
        let mut s = DedupSet::new(5);
        for i in 0..5 {
            assert!(s.insert(i));
        }
        s.reset();
        for i in 0..5 {
            assert!(!s.contains(i));
            assert!(s.insert(i));
        }
    }

    #[test]
    fn epoch_wrap_is_safe() {
        let mut s = DedupSet::new(3);
        s.insert(0);
        // Force the wrap path.
        s.epoch = u32::MAX;
        s.insert(1);
        assert!(s.contains(1));
        s.reset(); // wraps to 0 → full clear → epoch 1
        assert!(!s.contains(0));
        assert!(!s.contains(1));
        assert!(s.insert(1));
    }

    #[test]
    fn ensure_capacity_grows() {
        let mut s = DedupSet::new(2);
        s.insert(1);
        s.ensure_capacity(10);
        assert!(s.contains(1));
        assert!(s.insert(9));
        assert_eq!(s.capacity(), 10);
    }
}
