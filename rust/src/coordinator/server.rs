//! The serving engine: threaded request loop wiring batcher → workers.
//!
//! Topology: callers hold a cheap cloneable [`ServeHandle`]; requests flow
//! through a bounded mpsc into a batcher thread that forms batches
//! (`collect_batch_adaptive`) and dispatches them to a pool of worker
//! threads running the grouped batched executor
//! (`CollectionSearcher::search_batch_into` with a per-worker persistent
//! `BatchPool`). Admission is adaptive: an
//! in-flight batch counter shared with the workers tells the batcher
//! whether anyone is idle — if so the batch goes out immediately (plus
//! whatever backlog already queued), and the `max_wait_us` accumulation
//! window is only paid when all workers are busy and the wait hides
//! behind running work.
//! Bounded channels give backpressure end-to-end: when workers fall
//! behind, `try_send` fails and callers see `Error::Coordinator` instead
//! of unbounded queue growth.
//!
//! Workers read the index through one [`SnapshotCell`] **per shard**
//! (epoch-style `Arc` swaps): each batch loads every shard's current
//! [`IndexSnapshot`], so a `Collection` publishing per-shard mutations
//! (see [`ServeEngine::start_collection`]), a `MutableIndex` publishing
//! into a shared cell ([`ServeEngine::start_shared`]), or an explicit
//! [`ServeEngine::swap_shard_snapshot`] all take effect at batch
//! granularity without blocking, erroring, or even synchronizing with
//! in-flight queries: they finish on the snapshots they started with. A
//! single-shard engine behaves exactly like the pre-collection stack.

use crate::util::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use crate::util::sync::Mutex;
use std::sync::mpsc::{RecvTimeoutError, SyncSender, TrySendError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::config::{SearchParams, ServeConfig};
use crate::coordinator::batcher::{collect_batch_adaptive, QueryRequest};
use crate::coordinator::metrics::ServeMetrics;
use crate::error::{Error, Result};
use crate::index::{
    BatchPool, Collection, CollectionSearcher, CollectionSnapshot, IndexSnapshot, Search,
    SnapshotCell, SoarIndex,
};
use crate::linalg::topk::Scored;
use crate::linalg::MatrixF32;
use crate::runtime::Engine;

/// A running serving stack. Dropping it (or calling
/// [`ServeEngine::shutdown`]) closes intake and joins all threads.
pub struct ServeEngine {
    handle: Option<ServeHandle>,
    threads: Vec<JoinHandle<()>>,
    stop: Arc<AtomicBool>,
    /// One snapshot cell per shard, in shard order.
    cells: Arc<Vec<Arc<SnapshotCell>>>,
}

/// Cheap, cloneable client handle (blocking API).
#[derive(Clone)]
pub struct ServeHandle {
    tx: SyncSender<QueryRequest>,
    metrics: Arc<ServeMetrics>,
    dim: usize,
}

impl ServeEngine {
    /// Start the stack over a frozen index (wrapped as a single-segment
    /// snapshot in a private cell; use [`ServeEngine::swap_snapshot`] to
    /// replace it later).
    pub fn start(
        index: Arc<SoarIndex>,
        engine: Arc<Engine>,
        params: SearchParams,
        config: ServeConfig,
    ) -> ServeEngine {
        let cell = Arc::new(SnapshotCell::new(Arc::new(IndexSnapshot::from_index(index))));
        ServeEngine::start_shared(cell, engine, params, config)
    }

    /// Start the stack over a shared [`SnapshotCell`] — pass
    /// `MutableIndex::cell()` and every published mutation becomes
    /// visible to the next batch, with zero coordination on the query
    /// path.
    pub fn start_shared(
        snapshots: Arc<SnapshotCell>,
        engine: Arc<Engine>,
        params: SearchParams,
        config: ServeConfig,
    ) -> ServeEngine {
        ServeEngine::start_cells(vec![snapshots], engine, params, config)
    }

    /// Start the stack over a [`Collection`]: workers read every shard's
    /// cell per batch and fan out, so each shard's published mutations —
    /// including background-compaction swaps — become visible at batch
    /// granularity, per shard, with no global swap.
    pub fn start_collection(
        collection: &Collection,
        params: SearchParams,
        config: ServeConfig,
    ) -> ServeEngine {
        ServeEngine::start_cells(collection.cells(), collection.engine().clone(), params, config)
    }

    /// Start the stack over explicit per-shard cells (the primitive the
    /// other constructors reduce to).
    pub fn start_cells(
        cells: Vec<Arc<SnapshotCell>>,
        engine: Arc<Engine>,
        params: SearchParams,
        config: ServeConfig,
    ) -> ServeEngine {
        assert!(!cells.is_empty(), "serving needs at least one shard cell");
        let cells = Arc::new(cells);
        let (tx, rx) = std::sync::mpsc::sync_channel::<QueryRequest>(config.queue_depth.max(1));
        let metrics = Arc::new(ServeMetrics::default());
        let dim = cells[0].load().dim();

        // Batch channel: batcher → workers; small bound so the batcher
        // itself backs off instead of queueing unboundedly.
        let (btx, brx) = std::sync::mpsc::sync_channel::<Vec<QueryRequest>>(
            config.workers.max(1) * 2,
        );
        let brx = Arc::new(Mutex::new(brx));

        let stop = Arc::new(AtomicBool::new(false));
        // Batches dispatched but not yet finished by a worker; the
        // batcher reads it to decide whether waiting for more requests
        // would hide behind running work (all workers busy) or just add
        // latency (someone is idle).
        let in_flight = Arc::new(AtomicUsize::new(0));
        let mut threads = Vec::new();
        // Batcher thread: polls intake with a short timeout so it can
        // observe `stop` even while client handles keep the channel open.
        {
            let max_batch = config.max_batch.max(1);
            let wait = Duration::from_micros(config.max_wait_us);
            let workers = config.workers.max(1);
            let stop = stop.clone();
            let in_flight = in_flight.clone();
            let metrics = metrics.clone();
            threads.push(
                std::thread::Builder::new()
                    .name("soar-batcher".into())
                    .spawn(move || loop {
                        if stop.load(Ordering::Relaxed) {
                            break;
                        }
                        match rx.recv_timeout(Duration::from_millis(20)) {
                            Ok(first) => {
                                let busy = in_flight.load(Ordering::Relaxed) >= workers;
                                let batch =
                                    collect_batch_adaptive(first, &rx, max_batch, wait, busy);
                                metrics.record_admission(busy);
                                in_flight.fetch_add(1, Ordering::Relaxed);
                                if btx.send(batch).is_err() {
                                    break; // workers gone
                                }
                            }
                            Err(RecvTimeoutError::Timeout) => continue,
                            Err(RecvTimeoutError::Disconnected) => break,
                        }
                    })
                    .expect("spawn batcher"),
            );
        }
        // Worker threads. Each batch loads every shard's snapshot current
        // at batch start; a concurrent swap never blocks or fails a
        // request. Every worker owns a persistent [`BatchPool`], so the
        // grouped batched executor's plans, arenas, and scratches are
        // warm across batches — steady-state batches of a stable shape
        // perform zero allocator calls inside the search itself.
        for w in 0..config.workers.max(1) {
            let brx = brx.clone();
            let cells = cells.clone();
            let engine = engine.clone();
            let metrics = metrics.clone();
            let in_flight = in_flight.clone();
            threads.push(
                std::thread::Builder::new()
                    .name(format!("soar-worker-{w}"))
                    .spawn(move || {
                        let mut pool = BatchPool::new();
                        loop {
                            let batch = {
                                let guard = brx.lock().unwrap();
                                guard.recv()
                            };
                            match batch {
                                Ok(batch) => {
                                    let snapshot = CollectionSnapshot {
                                        shards: cells.iter().map(|c| c.load()).collect(),
                                    };
                                    run_batch(
                                        &snapshot, &engine, &params, batch, &metrics, &mut pool,
                                    );
                                    in_flight.fetch_sub(1, Ordering::Relaxed);
                                }
                                Err(_) => break, // batcher shut down
                            }
                        }
                    })
                    .expect("spawn worker"),
            );
        }

        ServeEngine {
            handle: Some(ServeHandle { tx, metrics, dim }),
            threads,
            stop,
            cells,
        }
    }

    /// Shards this engine serves.
    pub fn num_shards(&self) -> usize {
        self.cells.len()
    }

    /// Publish a new snapshot to a single-shard engine (epoch-style `Arc`
    /// swap). In-flight batches finish on their current snapshot;
    /// subsequent batches read the new one. Multi-shard engines must use
    /// [`ServeEngine::swap_shard_snapshot`].
    pub fn swap_snapshot(&self, snapshot: Arc<IndexSnapshot>) -> Result<()> {
        if self.cells.len() != 1 {
            return Err(Error::Coordinator(format!(
                "swap_snapshot on a {}-shard engine; use swap_shard_snapshot",
                self.cells.len()
            )));
        }
        self.swap_shard_snapshot(0, snapshot)
    }

    /// Publish a new snapshot for one shard. The other shards keep
    /// serving their current snapshots — the swap unit is the shard.
    pub fn swap_shard_snapshot(&self, shard: usize, snapshot: Arc<IndexSnapshot>) -> Result<()> {
        let cell = self.cells.get(shard).ok_or_else(|| {
            Error::Coordinator(format!(
                "shard {shard} out of range ({} shards)",
                self.cells.len()
            ))
        })?;
        let current = cell.load();
        if snapshot.dim() != current.dim() {
            return Err(Error::Shape(format!(
                "snapshot dim {} != serving dim {}",
                snapshot.dim(),
                current.dim()
            )));
        }
        cell.store(snapshot);
        Ok(())
    }

    /// The snapshot the workers currently read. Single-shard engines
    /// only — a multi-shard engine has no "the" snapshot (panics; use
    /// [`ServeEngine::current_collection_snapshot`]), matching the
    /// [`ServeEngine::swap_snapshot`] guard so legacy callers can't
    /// silently operate on one shard of a collection.
    pub fn current_snapshot(&self) -> Arc<IndexSnapshot> {
        assert_eq!(
            self.cells.len(),
            1,
            "current_snapshot on a multi-shard engine; use current_collection_snapshot"
        );
        self.cells[0].load()
    }

    /// A point-in-time view across every served shard.
    pub fn current_collection_snapshot(&self) -> CollectionSnapshot {
        CollectionSnapshot {
            shards: self.cells.iter().map(|c| c.load()).collect(),
        }
    }

    /// The serving cell (for wiring a `MutableIndex` up after start).
    /// Single-shard engines only, like [`ServeEngine::current_snapshot`].
    pub fn snapshot_cell(&self) -> Arc<SnapshotCell> {
        assert_eq!(
            self.cells.len(),
            1,
            "snapshot_cell on a multi-shard engine; collections own their cells"
        );
        self.cells[0].clone()
    }

    pub fn handle(&self) -> ServeHandle {
        self.handle.as_ref().expect("engine running").clone()
    }

    pub fn metrics(&self) -> Arc<ServeMetrics> {
        self.handle.as_ref().expect("engine running").metrics.clone()
    }

    /// Graceful shutdown: signal stop, join batcher + workers. In-flight
    /// requests that were never drained observe a closed response channel.
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::Relaxed);
        self.handle = None; // drop our sender
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

impl Drop for ServeEngine {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        self.handle = None;
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

/// Execute one batch on a worker thread: per-shard fan-out through the
/// shared [`Search`] trait (a 1-shard snapshot delegates straight to the
/// plain `SnapshotSearcher` path). Results land in the worker's
/// persistent `pool`, so the grouped executor's pooled state survives
/// across batches.
fn run_batch(
    snapshot: &CollectionSnapshot,
    engine: &Engine,
    params: &SearchParams,
    batch: Vec<QueryRequest>,
    metrics: &ServeMetrics,
    pool: &mut BatchPool,
) {
    let searcher = CollectionSearcher::new(snapshot, engine);
    let dim = searcher.dim();
    let mut queries = MatrixF32::zeros(batch.len(), dim);
    for (i, req) in batch.iter().enumerate() {
        queries.row_mut(i).copy_from_slice(&req.query);
    }
    if let Err(e) = searcher.search_batch_into(&queries, params, pool) {
        eprintln!("worker batch failed: {e}");
        // Drop senders: callers observe a closed channel.
        return;
    }
    // Record metrics BEFORE releasing responses: a client that returns
    // from `search` must observe its own query in the counters.
    let now = Instant::now();
    let latencies: Vec<u64> = batch
        .iter()
        .map(|req| now.duration_since(req.enqueued).as_micros() as u64)
        .collect();
    metrics.record_batch(latencies.len(), &latencies);
    let (lists, bytes) = pool
        .results()
        .iter()
        .fold((0u64, 0u64), |(l, b), (_, stats)| {
            (
                l + stats.lists_scanned as u64,
                b + stats.code_bytes_streamed as u64,
            )
        });
    metrics.record_scan_work(lists, bytes);
    for (req, (res, _stats)) in batch.into_iter().zip(pool.results()) {
        let mut res = res.clone();
        if let Some(k) = req.k {
            res.truncate(k);
        }
        let _ = req.respond.try_send(res);
    }
}

impl ServeHandle {
    /// Submit a query and block for the top-k results.
    pub fn search(&self, query: Vec<f32>) -> Result<Vec<Scored>> {
        self.search_k(query, None)
    }

    /// Submit with a per-request k override.
    pub fn search_k(&self, query: Vec<f32>, k: Option<usize>) -> Result<Vec<Scored>> {
        if query.len() != self.dim {
            return Err(Error::Shape(format!(
                "query dim {} != index dim {}",
                query.len(),
                self.dim
            )));
        }
        let (otx, orx) = std::sync::mpsc::sync_channel(1);
        let req = QueryRequest {
            query,
            k,
            enqueued: Instant::now(),
            respond: otx,
        };
        self.tx.try_send(req).map_err(|e| match e {
            TrySendError::Full(_) => {
                self.metrics.record_rejected();
                Error::Coordinator("queue full (backpressure)".into())
            }
            TrySendError::Disconnected(_) => {
                Error::Coordinator("serving stack shut down".into())
            }
        })?;
        orx.recv()
            .map_err(|_| Error::Coordinator("worker dropped request".into()))
    }

    pub fn metrics(&self) -> Arc<ServeMetrics> {
        self.metrics.clone()
    }
}

/// Drive a closed-loop load test against a handle from `threads`
/// concurrent clients, each issuing `queries_per_client` queries drawn
/// round-robin from `queries`. Returns wall-clock seconds.
pub fn closed_loop_load(
    handle: &ServeHandle,
    queries: &MatrixF32,
    threads: usize,
    queries_per_client: usize,
) -> f64 {
    let start = Instant::now();
    std::thread::scope(|s| {
        for t in 0..threads {
            let handle = handle.clone();
            s.spawn(move || {
                for i in 0..queries_per_client {
                    let qi = (t * queries_per_client + i) % queries.rows();
                    // Retry on backpressure: closed-loop clients wait.
                    loop {
                        match handle.search(queries.row(qi).to_vec()) {
                            Ok(_) => break,
                            Err(Error::Coordinator(msg)) if msg.contains("backpressure") => {
                                std::thread::sleep(Duration::from_micros(50));
                            }
                            Err(_) => return,
                        }
                    }
                }
            });
        }
    });
    start.elapsed().as_secs_f64()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{IndexConfig, SpillMode};
    use crate::data::ground_truth::ground_truth_mips;
    use crate::data::synthetic::SyntheticConfig;
    use crate::index::build_index;

    fn serve_fixture() -> (crate::data::Dataset, Arc<SoarIndex>, Arc<Engine>) {
        let ds = SyntheticConfig::glove_like(1500, 16, 32, 71).generate();
        let engine = Arc::new(Engine::cpu());
        let cfg = IndexConfig {
            num_partitions: 30,
            spill: SpillMode::Soar { lambda: 1.0 },
            ..Default::default()
        };
        let idx = Arc::new(build_index(&engine, &ds.data, &cfg).unwrap());
        (ds, idx, engine)
    }

    #[test]
    fn serves_queries_with_reasonable_recall() {
        let (ds, idx, engine) = serve_fixture();
        let gt = ground_truth_mips(&ds.data, &ds.queries, 10);
        let params = SearchParams {
            k: 10,
            top_t: 12,
            rerank_budget: 300,
        };
        let server = ServeEngine::start(idx, engine, params, ServeConfig::default());
        let handle = server.handle();
        let mut results = Vec::new();
        for qi in 0..ds.num_queries() {
            let res = handle.search(ds.queries.row(qi).to_vec()).unwrap();
            assert!(res.len() <= 10);
            results.push(res.into_iter().map(|s| s.id).collect::<Vec<_>>());
        }
        let recall = gt.mean_recall(&results);
        assert!(recall > 0.6, "served recall {recall}");
        let snap = server.metrics().snapshot();
        assert_eq!(snap.queries, ds.num_queries() as u64);
        assert!(snap.p99_us > 0);
        server.shutdown();
    }

    #[test]
    fn concurrent_load_batches() {
        let (ds, idx, engine) = serve_fixture();
        let params = SearchParams::default();
        let config = ServeConfig {
            max_batch: 16,
            max_wait_us: 2000,
            workers: 2,
            queue_depth: 1024,
        };
        let server = ServeEngine::start(idx, engine, params, config);
        let handle = server.handle();
        let elapsed = closed_loop_load(&handle, &ds.queries, 8, 8);
        assert!(elapsed > 0.0);
        let snap = server.metrics().snapshot();
        assert_eq!(snap.queries, 64);
        // concurrency must actually produce multi-query batches
        assert!(snap.mean_batch > 1.0, "mean batch {}", snap.mean_batch);
        server.shutdown();
    }

    #[test]
    fn idle_queries_skip_the_batching_window() {
        let (ds, idx, engine) = serve_fixture();
        // A batching window far larger than any search: the old
        // always-wait policy would pay 500ms per sequential query.
        let config = ServeConfig {
            max_batch: 64,
            max_wait_us: 500_000,
            workers: 2,
            queue_depth: 64,
        };
        let server = ServeEngine::start(idx, engine, SearchParams::default(), config);
        let handle = server.handle();
        let start = Instant::now();
        for qi in 0..4 {
            handle.search(ds.queries.row(qi).to_vec()).unwrap();
        }
        let elapsed = start.elapsed();
        assert!(
            elapsed < Duration::from_millis(400),
            "4 idle queries took {elapsed:?}; adaptive admission should not pay the window"
        );
        let snap = server.metrics().snapshot();
        assert_eq!(snap.queries, 4);
        assert_eq!(snap.immediate_batches, 4, "all dispatches had an idle worker");
        assert_eq!(snap.waited_batches, 0);
        server.shutdown();
    }

    #[test]
    fn rejects_wrong_dim_and_k_override() {
        let (ds, idx, engine) = serve_fixture();
        let server = ServeEngine::start(
            idx,
            engine,
            SearchParams::default(),
            ServeConfig::default(),
        );
        let handle = server.handle();
        assert!(handle.search(vec![0.0; 3]).is_err());
        let res = handle.search_k(ds.queries.row(0).to_vec(), Some(3)).unwrap();
        assert!(res.len() <= 3);
        server.shutdown();
    }

    #[test]
    fn shutdown_closes_handle() {
        let (ds, idx, engine) = serve_fixture();
        let server = ServeEngine::start(
            idx,
            engine,
            SearchParams::default(),
            ServeConfig::default(),
        );
        let handle = server.handle();
        server.shutdown();
        let err = handle.search(ds.queries.row(0).to_vec());
        assert!(err.is_err());
    }

    #[test]
    fn swap_snapshot_changes_results_without_errors() {
        let (ds, idx, engine) = serve_fixture();
        let server = ServeEngine::start(
            idx.clone(),
            engine.clone(),
            SearchParams::default(),
            ServeConfig::default(),
        );
        let handle = server.handle();
        let before = handle.search(ds.queries.row(0).to_vec()).unwrap();
        assert!(!before.is_empty());

        // Swap in a snapshot that tombstones the current top hit.
        let top = before[0].id;
        let base = server.current_snapshot();
        let mut tombs = (*base.tombstones).clone();
        tombs.insert(top);
        let swapped = Arc::new(crate::index::IndexSnapshot::new(
            base.sealed.clone(),
            base.delta.clone(),
            Arc::new(tombs),
            base.epoch + 1,
        ));
        server.swap_snapshot(swapped).unwrap();
        let after = handle.search(ds.queries.row(0).to_vec()).unwrap();
        assert!(
            after.iter().all(|s| s.id != top),
            "tombstoned id {top} must vanish after the swap"
        );

        // Dim mismatch is rejected.
        let ds2 = SyntheticConfig::glove_like(300, 8, 2, 9).generate();
        let cfg2 = IndexConfig {
            num_partitions: 6,
            spill: SpillMode::None,
            ..Default::default()
        };
        let idx2 = Arc::new(build_index(&engine, &ds2.data, &cfg2).unwrap());
        let bad = Arc::new(crate::index::IndexSnapshot::from_index(idx2));
        assert!(server.swap_snapshot(bad).is_err());
        server.shutdown();
    }

    #[test]
    fn serves_a_sharded_collection_with_per_shard_swaps() {
        use crate::config::{CollectionConfig, MutableConfig, ShardRouting};
        use crate::index::Collection;
        use crate::linalg::Rng;

        let ds = SyntheticConfig::glove_like(1500, 16, 24, 73).generate();
        let engine = Arc::new(Engine::cpu());
        let icfg = IndexConfig {
            num_partitions: 30,
            spill: SpillMode::Soar { lambda: 1.0 },
            ..Default::default()
        };
        let ccfg = CollectionConfig {
            num_shards: 3,
            routing: ShardRouting::Hash,
            mutable: MutableConfig {
                auto_compact: false,
                ..Default::default()
            },
            background_compact: false,
            maintenance: Default::default(),
            durability: Default::default(),
        };
        let c = Collection::build(engine.clone(), &ds.data, &icfg, ccfg).unwrap();
        let params = SearchParams {
            k: 10,
            top_t: 30, // full probe in every shard
            rerank_budget: 300,
        };
        let server = ServeEngine::start_collection(&c, params, ServeConfig::default());
        assert_eq!(server.num_shards(), 3);
        let handle = server.handle();
        let gt = ground_truth_mips(&ds.data, &ds.queries, 10);
        let mut results = Vec::new();
        for qi in 0..ds.num_queries() {
            let res = handle.search(ds.queries.row(qi).to_vec()).unwrap();
            assert!(res.len() <= 10);
            results.push(res.into_iter().map(|s| s.id).collect::<Vec<_>>());
        }
        let recall = gt.mean_recall(&results);
        assert!(recall > 0.6, "collection-served recall {recall}");

        // A mutation published by the collection reaches the next batch —
        // only its own shard's cell swapped.
        let mut rng = Rng::new(74);
        let mut v = ds.data.row(3).to_vec();
        for x in v.iter_mut() {
            *x += 0.1 * rng.next_gaussian();
        }
        crate::linalg::normalize(&mut v);
        c.upsert(9000, &v).unwrap();
        let res = handle.search(v.clone()).unwrap();
        assert_eq!(res[0].id, 9000, "published upsert must be servable");

        // Swap granularity is the shard.
        assert!(
            server.swap_snapshot(c.shard(0).snapshot()).is_err(),
            "whole-engine swap is a single-shard API"
        );
        assert!(server.swap_shard_snapshot(7, c.shard(0).snapshot()).is_err());
        server.swap_shard_snapshot(1, c.shard(1).snapshot()).unwrap();
        assert_eq!(server.current_collection_snapshot().num_shards(), 3);
        server.shutdown();
    }

    #[test]
    fn backpressure_rejects_when_queue_full() {
        let (ds, idx, engine) = serve_fixture();
        // Tiny queue + slow flush window: flood until rejection.
        let config = ServeConfig {
            max_batch: 1,
            max_wait_us: 50_000,
            workers: 1,
            queue_depth: 1,
        };
        let server = ServeEngine::start(idx, engine, SearchParams::default(), config);
        let handle = server.handle();
        let mut saw_reject = false;
        // Fire-and-forget senders from a side thread while main floods.
        std::thread::scope(|s| {
            for _ in 0..4 {
                let h = handle.clone();
                let q = ds.queries.row(0).to_vec();
                s.spawn(move || {
                    for _ in 0..8 {
                        let _ = h.search(q.clone());
                    }
                });
            }
            for _ in 0..64 {
                if handle.search(ds.queries.row(0).to_vec()).is_err() {
                    saw_reject = true;
                    break;
                }
            }
        });
        // Either we observed explicit backpressure or the tiny stack kept
        // up; both are legal, but metrics must be consistent.
        let snap = server.metrics().snapshot();
        assert!(snap.queries > 0);
        if saw_reject {
            assert!(snap.rejected > 0);
        }
        server.shutdown();
    }
}
