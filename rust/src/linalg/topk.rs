//! Streaming top-k selection — the final stage of every search.
//!
//! A fixed-capacity binary min-heap on score: the root is the current k-th
//! best, so the common case (candidate worse than the k-th best) is a single
//! branch with no allocation. Used by both the ADC scan and the exact
//! rerank.

/// One scored candidate.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Scored {
    pub id: u32,
    pub score: f32,
}

/// Fixed-capacity top-k accumulator (max scores kept).
#[derive(Clone, Debug)]
pub struct TopK {
    k: usize,
    // min-heap on score: heap[0] is the weakest of the kept candidates.
    heap: Vec<Scored>,
}

impl TopK {
    pub fn new(k: usize) -> Self {
        assert!(k > 0, "k must be positive");
        TopK {
            k,
            heap: Vec::with_capacity(k),
        }
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.heap.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Current admission threshold: candidates with score ≤ this are
    /// rejected once the heap is full. `-inf` while not yet full.
    #[inline]
    pub fn threshold(&self) -> f32 {
        if self.heap.len() < self.k {
            f32::NEG_INFINITY
        } else {
            self.heap[0].score
        }
    }

    // serve-path: no-panic begin (admission and drain run per candidate
    // inside the scan loop; nothing here may unwrap)
    /// Offer a candidate; O(1) when rejected, O(log k) when admitted.
    #[inline]
    pub fn push(&mut self, id: u32, score: f32) {
        if self.heap.len() < self.k {
            self.heap.push(Scored { id, score });
            self.sift_up(self.heap.len() - 1);
        } else if score > self.heap[0].score {
            self.heap[0] = Scored { id, score };
            self.sift_down(0);
        }
    }

    fn sift_up(&mut self, mut i: usize) {
        while i > 0 {
            let parent = (i - 1) / 2;
            if self.heap[i].score < self.heap[parent].score {
                self.heap.swap(i, parent);
                i = parent;
            } else {
                break;
            }
        }
    }

    fn sift_down(&mut self, mut i: usize) {
        let n = self.heap.len();
        loop {
            let l = 2 * i + 1;
            let r = l + 1;
            let mut smallest = i;
            if l < n && self.heap[l].score < self.heap[smallest].score {
                smallest = l;
            }
            if r < n && self.heap[r].score < self.heap[smallest].score {
                smallest = r;
            }
            if smallest == i {
                break;
            }
            self.heap.swap(i, smallest);
            i = smallest;
        }
    }

    /// Re-arm for a fresh accumulation of up to `k` candidates, retaining
    /// the heap's buffer. After the first call at a given `k`, subsequent
    /// resets at the same (or smaller) `k` never touch the allocator —
    /// this is what lets a reused scratch run allocation-free.
    pub fn reset(&mut self, k: usize) {
        assert!(k > 0, "k must be positive");
        self.k = k;
        self.heap.clear();
        self.heap.reserve(k);
    }

    /// Sort the kept candidates by descending score (ties by ascending id
    /// for determinism) and return them in place. The accumulator is no
    /// longer a valid heap afterwards; `reset` before the next use.
    pub fn sorted(&mut self) -> &[Scored] {
        Self::sort_desc(&mut self.heap);
        &self.heap
    }

    /// Like [`TopK::sorted`], but appends the sorted candidates into `out`
    /// (whose capacity is reused) and clears the accumulator.
    pub fn sort_into(&mut self, out: &mut Vec<Scored>) {
        Self::sort_desc(&mut self.heap);
        out.extend_from_slice(&self.heap);
        self.heap.clear();
    }

    /// Like [`TopK::sort_into`], but appends `(id, score)` pairs — the
    /// element type of the batched executor's flat partition table.
    pub fn sort_into_pairs(&mut self, out: &mut Vec<(u32, f32)>) {
        Self::sort_desc(&mut self.heap);
        for s in &self.heap {
            out.push((s.id, s.score));
        }
        self.heap.clear();
    }

    /// Drain into a `Vec` sorted by descending score (ties by ascending id
    /// for determinism).
    pub fn into_sorted(mut self) -> Vec<Scored> {
        Self::sort_desc(&mut self.heap);
        self.heap
    }

    fn sort_desc(items: &mut [Scored]) {
        items.sort_by(|a, b| {
            b.score
                .partial_cmp(&a.score)
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.id.cmp(&b.id))
        });
    }

    /// Clear for reuse without deallocating.
    pub fn clear(&mut self) {
        self.heap.clear();
    }
    // serve-path: no-panic end
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Rng;

    fn brute_topk(scores: &[(u32, f32)], k: usize) -> Vec<Scored> {
        let mut v: Vec<Scored> = scores
            .iter()
            .map(|&(id, score)| Scored { id, score })
            .collect();
        v.sort_by(|a, b| {
            b.score
                .partial_cmp(&a.score)
                .unwrap()
                .then(a.id.cmp(&b.id))
        });
        v.truncate(k);
        v
    }

    #[test]
    fn matches_brute_force() {
        let mut rng = Rng::new(9);
        for &(n, k) in &[(1usize, 1usize), (5, 3), (100, 10), (1000, 100), (50, 50), (10, 20)] {
            let scores: Vec<(u32, f32)> = (0..n)
                .map(|i| (i as u32, rng.next_gaussian()))
                .collect();
            let mut tk = TopK::new(k);
            for &(id, s) in &scores {
                tk.push(id, s);
            }
            assert_eq!(tk.into_sorted(), brute_topk(&scores, k));
        }
    }

    #[test]
    fn threshold_semantics() {
        let mut tk = TopK::new(2);
        assert_eq!(tk.threshold(), f32::NEG_INFINITY);
        tk.push(0, 1.0);
        assert_eq!(tk.threshold(), f32::NEG_INFINITY);
        tk.push(1, 3.0);
        assert_eq!(tk.threshold(), 1.0);
        tk.push(2, 2.0); // evicts score 1.0
        assert_eq!(tk.threshold(), 2.0);
        tk.push(3, 0.5); // rejected
        let out = tk.into_sorted();
        assert_eq!(out[0].id, 1);
        assert_eq!(out[1].id, 2);
    }

    #[test]
    fn reset_and_sort_into_reuse_buffers() {
        let mut tk = TopK::new(3);
        tk.push(1, 1.0);
        tk.push(2, 5.0);
        tk.push(3, 3.0);
        let mut out = Vec::new();
        tk.sort_into(&mut out);
        assert_eq!(out.iter().map(|s| s.id).collect::<Vec<_>>(), [2, 3, 1]);
        // Re-arm at a different k; prior contents must be gone.
        tk.reset(2);
        assert!(tk.is_empty());
        tk.push(4, 9.0);
        tk.push(5, 7.0);
        tk.push(6, 8.0);
        assert_eq!(tk.sorted().iter().map(|s| s.id).collect::<Vec<_>>(), [4, 6]);
        // sorted() leaves contents in place for a follow-up sort_into.
        out.clear();
        tk.sort_into(&mut out);
        assert_eq!(out.len(), 2);
        assert!(tk.is_empty());
    }

    #[test]
    fn sort_into_pairs_matches_sort_into() {
        let mut rng = Rng::new(11);
        let scores: Vec<(u32, f32)> = (0..40).map(|i| (i as u32, rng.next_gaussian())).collect();
        let mut a = TopK::new(7);
        let mut b = TopK::new(7);
        for &(id, s) in &scores {
            a.push(id, s);
            b.push(id, s);
        }
        let mut want = Vec::new();
        a.sort_into(&mut want);
        let mut got = vec![(999u32, 0.0f32)]; // appends after existing content
        b.sort_into_pairs(&mut got);
        assert!(b.is_empty());
        assert_eq!(got.len(), want.len() + 1);
        for (i, s) in want.iter().enumerate() {
            assert_eq!(got[i + 1], (s.id, s.score));
        }
    }

    #[test]
    fn clear_reuses() {
        let mut tk = TopK::new(4);
        tk.push(1, 1.0);
        tk.clear();
        assert!(tk.is_empty());
        tk.push(2, 2.0);
        assert_eq!(tk.into_sorted()[0].id, 2);
    }
}
