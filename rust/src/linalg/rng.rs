//! Deterministic RNG (PCG-XSH-RR 64/32) + Gaussian sampling.
//!
//! Every stochastic component of the engine (dataset synthesis, k-means
//! init, minibatch sampling) threads a seed through this type so builds and
//! experiments are exactly reproducible run-to-run.

/// PCG-XSH-RR 64/32: small, fast, statistically solid, stable across
/// platforms (unlike `std`'s unspecified hasher-based sources).
#[derive(Clone, Debug)]
pub struct Rng {
    state: u64,
    inc: u64,
    /// Cached second Box–Muller output.
    gauss_spare: Option<f32>,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Rng {
    /// Seeded generator; distinct `stream` values give independent
    /// sequences for the same seed.
    pub fn new(seed: u64) -> Self {
        Self::with_stream(seed, 0xda3e39cb94b95bdb)
    }

    pub fn with_stream(seed: u64, stream: u64) -> Self {
        let mut rng = Rng {
            state: 0,
            inc: (stream << 1) | 1,
            gauss_spare: None,
        };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in `[0, bound)` without modulo bias (Lemire rejection).
    #[inline]
    pub fn next_below(&mut self, bound: u32) -> u32 {
        assert!(bound > 0);
        let threshold = bound.wrapping_neg() % bound;
        loop {
            let r = self.next_u32();
            let m = (r as u64) * (bound as u64);
            if (m as u32) >= threshold {
                return (m >> 32) as u32;
            }
        }
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Standard normal via Box–Muller (cached pair).
    pub fn next_gaussian(&mut self) -> f32 {
        if let Some(v) = self.gauss_spare.take() {
            return v;
        }
        loop {
            let u1 = self.next_f32();
            if u1 <= f32::EPSILON {
                continue;
            }
            let u2 = self.next_f32();
            let mag = (-2.0 * u1.ln()).sqrt();
            let (s, c) = (2.0 * std::f32::consts::PI * u2).sin_cos();
            self.gauss_spare = Some(mag * s);
            return mag * c;
        }
    }

    /// Fill `out` with standard normals.
    pub fn fill_gaussian(&mut self, out: &mut [f32]) {
        for v in out.iter_mut() {
            *v = self.next_gaussian();
        }
    }

    /// Sample `k` distinct indices from `0..n` (Floyd's algorithm), in
    /// random order.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "cannot sample {k} from {n}");
        let mut chosen = std::collections::HashSet::with_capacity(k);
        let mut out = Vec::with_capacity(k);
        for j in (n - k)..n {
            let t = self.next_below((j + 1) as u32) as usize;
            if chosen.insert(t) {
                out.push(t);
            } else {
                chosen.insert(j);
                out.push(j);
            }
        }
        // light shuffle so order is unbiased
        for i in (1..out.len()).rev() {
            let j = self.next_below((i + 1) as u32) as usize;
            out.swap(i, j);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
        let mut c = Rng::new(43);
        let same = (0..100).filter(|_| a.next_u32() == c.next_u32()).count();
        assert!(same < 5);
    }

    #[test]
    fn uniform_f32_in_range_and_roughly_uniform() {
        let mut rng = Rng::new(1);
        let mut sum = 0.0f64;
        for _ in 0..10_000 {
            let v = rng.next_f32();
            assert!((0.0..1.0).contains(&v));
            sum += v as f64;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn gaussian_moments() {
        let mut rng = Rng::new(7);
        let n = 50_000;
        let (mut m, mut v) = (0.0f64, 0.0f64);
        for _ in 0..n {
            let x = rng.next_gaussian() as f64;
            m += x;
            v += x * x;
        }
        m /= n as f64;
        v = v / n as f64 - m * m;
        assert!(m.abs() < 0.02, "mean={m}");
        assert!((v - 1.0).abs() < 0.05, "var={v}");
    }

    #[test]
    fn next_below_bounds() {
        let mut rng = Rng::new(3);
        for bound in [1u32, 2, 7, 100] {
            for _ in 0..200 {
                assert!(rng.next_below(bound) < bound);
            }
        }
    }

    #[test]
    fn sample_indices_distinct_and_complete() {
        let mut rng = Rng::new(5);
        let s = rng.sample_indices(100, 20);
        assert_eq!(s.len(), 20);
        let set: std::collections::HashSet<_> = s.iter().collect();
        assert_eq!(set.len(), 20);
        assert!(s.iter().all(|&i| i < 100));
        // k == n returns a permutation
        let all = rng.sample_indices(10, 10);
        let set: std::collections::HashSet<_> = all.iter().collect();
        assert_eq!(set.len(), 10);
    }
}
