//! Row-major `f32` matrix — the storage type for datasets, codebooks, and
//! residuals. Contiguous storage keeps the scan loops prefetcher-friendly.

use crate::error::{Error, Result};

/// Dense row-major matrix of `f32`.
#[derive(Clone, Debug, PartialEq)]
pub struct MatrixF32 {
    rows: usize,
    cols: usize,
    data: Vec<f32>,
}

impl MatrixF32 {
    /// Zero-initialized `rows × cols` matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        MatrixF32 {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    /// Wrap an existing buffer; `data.len()` must equal `rows * cols`.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Result<Self> {
        // Checked product: deserializers hand this u64-derived shapes, and
        // a corrupted file must fail cleanly, not overflow.
        if rows.checked_mul(cols) != Some(data.len()) {
            return Err(Error::Shape(format!(
                "buffer len {} != {rows}x{cols}",
                data.len()
            )));
        }
        Ok(MatrixF32 { rows, cols, data })
    }

    /// Build from row slices (all must share a length).
    pub fn from_rows(rows: &[&[f32]]) -> Result<Self> {
        if rows.is_empty() {
            return Ok(MatrixF32::zeros(0, 0));
        }
        let cols = rows[0].len();
        let mut data = Vec::with_capacity(rows.len() * cols);
        for r in rows {
            if r.len() != cols {
                return Err(Error::Shape("ragged rows".into()));
            }
            data.extend_from_slice(r);
        }
        Ok(MatrixF32 {
            rows: rows.len(),
            cols,
            data,
        })
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn is_empty(&self) -> bool {
        self.rows == 0
    }

    /// Row `i` as a slice.
    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        debug_assert!(i < self.rows);
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Mutable row `i`.
    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        debug_assert!(i < self.rows);
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Whole backing buffer (row-major).
    pub fn as_slice(&self) -> &[f32] {
        &self.data
    }

    pub fn as_mut_slice(&mut self) -> &mut [f32] {
        &mut self.data
    }

    /// Consume into the backing buffer.
    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    /// Iterate rows.
    pub fn iter_rows(&self) -> impl Iterator<Item = &[f32]> {
        self.data.chunks_exact(self.cols.max(1))
    }

    /// Copy of the selected rows, in the given order.
    pub fn gather_rows(&self, indices: &[usize]) -> MatrixF32 {
        let mut out = MatrixF32::zeros(indices.len(), self.cols);
        for (dst, &src) in indices.iter().enumerate() {
            out.row_mut(dst).copy_from_slice(self.row(src));
        }
        out
    }

    /// Append a row.
    pub fn push_row(&mut self, row: &[f32]) -> Result<()> {
        if self.rows == 0 && self.cols == 0 {
            self.cols = row.len();
        }
        if row.len() != self.cols {
            return Err(Error::Shape(format!(
                "row len {} != cols {}",
                row.len(),
                self.cols
            )));
        }
        self.data.extend_from_slice(row);
        self.rows += 1;
        Ok(())
    }

    /// L2-normalize every row in place (zero rows untouched).
    pub fn normalize_rows(&mut self) {
        let cols = self.cols;
        for chunk in self.data.chunks_exact_mut(cols.max(1)) {
            super::normalize(chunk);
        }
    }

    /// Approximate heap size in bytes (used by the Table 1 memory report).
    pub fn memory_bytes(&self) -> usize {
        self.data.len() * std::mem::size_of::<f32>()
    }

    /// Reshape in place to `rows × cols`, zero-filled, reusing the backing
    /// buffer's capacity. Steady-state reuse at a fixed (or shrinking)
    /// shape never touches the allocator — this is what lets a pooled
    /// score matrix run allocation-free across batches.
    pub fn resize(&mut self, rows: usize, cols: usize) {
        self.rows = rows;
        self.cols = cols;
        self.data.clear();
        self.data.resize(rows * cols, 0.0);
    }
}

/// Tile of A rows held against one tile of B rows at a time.
const GEMM_TILE_A: usize = 8;
/// Tile of B rows kept hot in L1 while the A tile sweeps over it
/// (32 rows × 128 dims × 4 B = 16 KiB worst case for our shapes).
const GEMM_TILE_B: usize = 32;

/// Blocked `A·Bᵀ` into `out` (resized to `a.rows() × b.rows()`).
///
/// Cache tiling only: every output element is still produced by the exact
/// same [`dot`](super::dot) reduction as the naive two-loop form, so the
/// result is bit-identical to `out[i][j] = dot(a.row(i), b.row(j))` — the
/// blocking merely keeps a tile of B rows resident in L1 while a tile of
/// A rows reuses them instead of streaming all of B once per A row.
pub fn matmul_nt(a: &MatrixF32, b: &MatrixF32, out: &mut MatrixF32) {
    assert_eq!(a.cols(), b.cols(), "dim mismatch");
    out.resize(a.rows(), b.rows());
    matmul_nt_rows(a, 0, a.rows(), b, out.as_mut_slice());
}

/// Serial blocked kernel over the A-row range `[i0, i1)`; `out_rows` is the
/// row-major `(i1 - i0) × b.rows()` destination. Split out so callers can
/// parallelize over disjoint row ranges of a shared output buffer.
pub(crate) fn matmul_nt_rows(
    a: &MatrixF32,
    i0: usize,
    i1: usize,
    b: &MatrixF32,
    out_rows: &mut [f32],
) {
    let nb = b.rows();
    debug_assert_eq!(out_rows.len(), (i1 - i0) * nb);
    // hot-path: no-alloc begin (GEMM tile loops; the output was sized by
    // the caller, nothing below may touch the allocator)
    for ib in (i0..i1).step_by(GEMM_TILE_A) {
        let ie = (ib + GEMM_TILE_A).min(i1);
        for jb in (0..nb).step_by(GEMM_TILE_B) {
            let je = (jb + GEMM_TILE_B).min(nb);
            for i in ib..ie {
                let ai = a.row(i);
                let row = &mut out_rows[(i - i0) * nb..(i - i0 + 1) * nb];
                for j in jb..je {
                    row[j] = super::dot(ai, b.row(j));
                }
            }
        }
    }
    // hot-path: no-alloc end
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_access() {
        let m = MatrixF32::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]).unwrap();
        assert_eq!(m.rows(), 2);
        assert_eq!(m.cols(), 3);
        assert_eq!(m.row(1), &[4., 5., 6.]);
        assert!(MatrixF32::from_vec(2, 3, vec![0.0; 5]).is_err());
    }

    #[test]
    fn from_rows_and_ragged() {
        let m = MatrixF32::from_rows(&[&[1., 2.], &[3., 4.]]).unwrap();
        assert_eq!(m.row(0), &[1., 2.]);
        assert!(MatrixF32::from_rows(&[&[1., 2.], &[3.]]).is_err());
        let empty = MatrixF32::from_rows(&[]).unwrap();
        assert!(empty.is_empty());
    }

    #[test]
    fn gather_and_push() {
        let m = MatrixF32::from_rows(&[&[1., 1.], &[2., 2.], &[3., 3.]]).unwrap();
        let g = m.gather_rows(&[2, 0]);
        assert_eq!(g.row(0), &[3., 3.]);
        assert_eq!(g.row(1), &[1., 1.]);
        let mut m2 = MatrixF32::zeros(0, 0);
        m2.push_row(&[7., 8.]).unwrap();
        m2.push_row(&[9., 10.]).unwrap();
        assert_eq!(m2.rows(), 2);
        assert!(m2.push_row(&[1.]).is_err());
    }

    #[test]
    fn normalize_rows_unit_norm() {
        let mut m = MatrixF32::from_rows(&[&[3., 4.], &[0., 0.]]).unwrap();
        m.normalize_rows();
        assert!((crate::linalg::norm(m.row(0)) - 1.0).abs() < 1e-6);
        assert_eq!(m.row(1), &[0., 0.]); // zero row untouched
    }

    #[test]
    fn iter_rows_count() {
        let m = MatrixF32::zeros(4, 2);
        assert_eq!(m.iter_rows().count(), 4);
        assert_eq!(m.memory_bytes(), 4 * 2 * 4);
    }

    #[test]
    fn resize_reuses_capacity_and_zeroes() {
        let mut m = MatrixF32::from_vec(2, 2, vec![1., 2., 3., 4.]).unwrap();
        m.resize(3, 2);
        assert_eq!(m.rows(), 3);
        assert_eq!(m.as_slice(), &[0.0; 6]);
        let cap_ptr = m.as_slice().as_ptr();
        m.resize(2, 3); // same element count: buffer must not move
        assert_eq!(m.as_slice().as_ptr(), cap_ptr);
        m.resize(1, 2); // shrink: buffer must not move either
        assert_eq!(m.as_slice().as_ptr(), cap_ptr);
    }

    #[test]
    fn matmul_nt_is_bitwise_naive() {
        let mut rng = crate::linalg::Rng::new(17);
        // Shapes straddling both tile sizes, plus ragged remainders.
        for &(na, nb, d) in &[(1usize, 1usize, 3usize), (7, 33, 12), (9, 64, 5), (20, 100, 17)] {
            let mut a = MatrixF32::zeros(na, d);
            let mut b = MatrixF32::zeros(nb, d);
            for i in 0..na {
                rng.fill_gaussian(a.row_mut(i));
            }
            for j in 0..nb {
                rng.fill_gaussian(b.row_mut(j));
            }
            let mut out = MatrixF32::zeros(0, 0);
            matmul_nt(&a, &b, &mut out);
            assert_eq!(out.rows(), na);
            assert_eq!(out.cols(), nb);
            for i in 0..na {
                for j in 0..nb {
                    assert_eq!(
                        out.row(i)[j].to_bits(),
                        crate::linalg::dot(a.row(i), b.row(j)).to_bits(),
                        "({i},{j})"
                    );
                }
            }
        }
    }

    #[test]
    fn matmul_nt_empty_shapes() {
        let a = MatrixF32::zeros(0, 4);
        let b = MatrixF32::zeros(5, 4);
        let mut out = MatrixF32::zeros(3, 3);
        matmul_nt(&a, &b, &mut out);
        assert_eq!(out.rows(), 0);
        matmul_nt(&b, &a, &mut out);
        assert_eq!(out.rows(), 5);
        assert_eq!(out.cols(), 0);
    }
}
