//! Row-major `f32` matrix — the storage type for datasets, codebooks, and
//! residuals. Contiguous storage keeps the scan loops prefetcher-friendly.

use crate::error::{Error, Result};

/// Dense row-major matrix of `f32`.
#[derive(Clone, Debug, PartialEq)]
pub struct MatrixF32 {
    rows: usize,
    cols: usize,
    data: Vec<f32>,
}

impl MatrixF32 {
    /// Zero-initialized `rows × cols` matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        MatrixF32 {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    /// Wrap an existing buffer; `data.len()` must equal `rows * cols`.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Result<Self> {
        // Checked product: deserializers hand this u64-derived shapes, and
        // a corrupted file must fail cleanly, not overflow.
        if rows.checked_mul(cols) != Some(data.len()) {
            return Err(Error::Shape(format!(
                "buffer len {} != {rows}x{cols}",
                data.len()
            )));
        }
        Ok(MatrixF32 { rows, cols, data })
    }

    /// Build from row slices (all must share a length).
    pub fn from_rows(rows: &[&[f32]]) -> Result<Self> {
        if rows.is_empty() {
            return Ok(MatrixF32::zeros(0, 0));
        }
        let cols = rows[0].len();
        let mut data = Vec::with_capacity(rows.len() * cols);
        for r in rows {
            if r.len() != cols {
                return Err(Error::Shape("ragged rows".into()));
            }
            data.extend_from_slice(r);
        }
        Ok(MatrixF32 {
            rows: rows.len(),
            cols,
            data,
        })
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn is_empty(&self) -> bool {
        self.rows == 0
    }

    /// Row `i` as a slice.
    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        debug_assert!(i < self.rows);
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Mutable row `i`.
    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        debug_assert!(i < self.rows);
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Whole backing buffer (row-major).
    pub fn as_slice(&self) -> &[f32] {
        &self.data
    }

    pub fn as_mut_slice(&mut self) -> &mut [f32] {
        &mut self.data
    }

    /// Consume into the backing buffer.
    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    /// Iterate rows.
    pub fn iter_rows(&self) -> impl Iterator<Item = &[f32]> {
        self.data.chunks_exact(self.cols.max(1))
    }

    /// Copy of the selected rows, in the given order.
    pub fn gather_rows(&self, indices: &[usize]) -> MatrixF32 {
        let mut out = MatrixF32::zeros(indices.len(), self.cols);
        for (dst, &src) in indices.iter().enumerate() {
            out.row_mut(dst).copy_from_slice(self.row(src));
        }
        out
    }

    /// Append a row.
    pub fn push_row(&mut self, row: &[f32]) -> Result<()> {
        if self.rows == 0 && self.cols == 0 {
            self.cols = row.len();
        }
        if row.len() != self.cols {
            return Err(Error::Shape(format!(
                "row len {} != cols {}",
                row.len(),
                self.cols
            )));
        }
        self.data.extend_from_slice(row);
        self.rows += 1;
        Ok(())
    }

    /// L2-normalize every row in place (zero rows untouched).
    pub fn normalize_rows(&mut self) {
        let cols = self.cols;
        for chunk in self.data.chunks_exact_mut(cols.max(1)) {
            super::normalize(chunk);
        }
    }

    /// Approximate heap size in bytes (used by the Table 1 memory report).
    pub fn memory_bytes(&self) -> usize {
        self.data.len() * std::mem::size_of::<f32>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_access() {
        let m = MatrixF32::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]).unwrap();
        assert_eq!(m.rows(), 2);
        assert_eq!(m.cols(), 3);
        assert_eq!(m.row(1), &[4., 5., 6.]);
        assert!(MatrixF32::from_vec(2, 3, vec![0.0; 5]).is_err());
    }

    #[test]
    fn from_rows_and_ragged() {
        let m = MatrixF32::from_rows(&[&[1., 2.], &[3., 4.]]).unwrap();
        assert_eq!(m.row(0), &[1., 2.]);
        assert!(MatrixF32::from_rows(&[&[1., 2.], &[3.]]).is_err());
        let empty = MatrixF32::from_rows(&[]).unwrap();
        assert!(empty.is_empty());
    }

    #[test]
    fn gather_and_push() {
        let m = MatrixF32::from_rows(&[&[1., 1.], &[2., 2.], &[3., 3.]]).unwrap();
        let g = m.gather_rows(&[2, 0]);
        assert_eq!(g.row(0), &[3., 3.]);
        assert_eq!(g.row(1), &[1., 1.]);
        let mut m2 = MatrixF32::zeros(0, 0);
        m2.push_row(&[7., 8.]).unwrap();
        m2.push_row(&[9., 10.]).unwrap();
        assert_eq!(m2.rows(), 2);
        assert!(m2.push_row(&[1.]).is_err());
    }

    #[test]
    fn normalize_rows_unit_norm() {
        let mut m = MatrixF32::from_rows(&[&[3., 4.], &[0., 0.]]).unwrap();
        m.normalize_rows();
        assert!((crate::linalg::norm(m.row(0)) - 1.0).abs() < 1e-6);
        assert_eq!(m.row(1), &[0., 0.]); // zero row untouched
    }

    #[test]
    fn iter_rows_count() {
        let m = MatrixF32::zeros(4, 2);
        assert_eq!(m.iter_rows().count(), 4);
        assert_eq!(m.memory_bytes(), 4 * 2 * 4);
    }
}
