//! Small dense linear-algebra kernels used throughout the engine.
//!
//! Everything here is deliberately allocation-free on the hot path and
//! written so LLVM auto-vectorizes the inner loops (the ADC scan and the
//! scoring fallback live downstream of these).

pub mod matrix;
pub mod rng;
pub mod topk;

pub use matrix::{matmul_nt, MatrixF32};
pub use rng::Rng;
pub use topk::TopK;

/// Inner product ⟨a, b⟩. Panics in debug builds on length mismatch.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    // 4-way unrolled accumulation: breaks the fp dependency chain so LLVM
    // emits vectorized fma loops even at default `-C opt-level=3`.
    let n = a.len();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
    for i in 0..chunks {
        let j = i * 4;
        s0 += a[j] * b[j];
        s1 += a[j + 1] * b[j + 1];
        s2 += a[j + 2] * b[j + 2];
        s3 += a[j + 3] * b[j + 3];
    }
    let mut s = s0 + s1 + s2 + s3;
    for j in chunks * 4..n {
        s += a[j] * b[j];
    }
    s
}

/// Mixed inner product ⟨q, rec⟩ with an int8 right-hand side — the rerank
/// inner loop shared by both searchers. `q` is the query pre-multiplied by
/// the per-dimension int8 scales, so the product is directly a score.
#[inline]
pub fn dot_i8(q: &[f32], rec: &[i8]) -> f32 {
    debug_assert_eq!(q.len(), rec.len());
    let n = q.len();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
    for i in 0..chunks {
        let j = i * 4;
        s0 += q[j] * rec[j] as f32;
        s1 += q[j + 1] * rec[j + 1] as f32;
        s2 += q[j + 2] * rec[j + 2] as f32;
        s3 += q[j + 3] * rec[j + 3] as f32;
    }
    let mut s = s0 + s1 + s2 + s3;
    for j in chunks * 4..n {
        s += q[j] * rec[j] as f32;
    }
    s
}

/// Squared Euclidean distance ‖a − b‖².
#[inline]
pub fn squared_l2(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
    for i in 0..chunks {
        let j = i * 4;
        let d0 = a[j] - b[j];
        let d1 = a[j + 1] - b[j + 1];
        let d2 = a[j + 2] - b[j + 2];
        let d3 = a[j + 3] - b[j + 3];
        s0 += d0 * d0;
        s1 += d1 * d1;
        s2 += d2 * d2;
        s3 += d3 * d3;
    }
    let mut s = s0 + s1 + s2 + s3;
    for j in chunks * 4..n {
        let d = a[j] - b[j];
        s += d * d;
    }
    s
}

/// Euclidean norm ‖a‖.
#[inline]
pub fn norm(a: &[f32]) -> f32 {
    dot(a, a).sqrt()
}

/// `out = a - b`, elementwise.
#[inline]
pub fn sub(a: &[f32], b: &[f32], out: &mut [f32]) {
    debug_assert!(a.len() == b.len() && b.len() == out.len());
    for i in 0..a.len() {
        out[i] = a[i] - b[i];
    }
}

/// `a += alpha * b`.
#[inline]
pub fn axpy(alpha: f32, b: &[f32], a: &mut [f32]) {
    debug_assert_eq!(a.len(), b.len());
    for i in 0..a.len() {
        a[i] += alpha * b[i];
    }
}

/// Scale `a` in place.
#[inline]
pub fn scale(a: &mut [f32], s: f32) {
    for v in a.iter_mut() {
        *v *= s;
    }
}

/// Normalize `a` to unit norm in place; zero vectors are left untouched.
/// Returns the original norm.
#[inline]
pub fn normalize(a: &mut [f32]) -> f32 {
    let n = norm(a);
    if n > 0.0 {
        scale(a, 1.0 / n);
    }
    n
}

/// Cosine of the angle between `a` and `b`; 0.0 if either is zero.
#[inline]
pub fn cosine(a: &[f32], b: &[f32]) -> f32 {
    let na = norm(a);
    let nb = norm(b);
    if na == 0.0 || nb == 0.0 {
        0.0
    } else {
        dot(a, b) / (na * nb)
    }
}

/// Index of the minimum value. Panics on empty input.
#[inline]
pub fn argmin(values: &[f32]) -> usize {
    assert!(!values.is_empty());
    let mut best = 0usize;
    let mut bv = values[0];
    for (i, &v) in values.iter().enumerate().skip(1) {
        if v < bv {
            bv = v;
            best = i;
        }
    }
    best
}

/// Index of the maximum value. Panics on empty input.
#[inline]
pub fn argmax(values: &[f32]) -> usize {
    assert!(!values.is_empty());
    let mut best = 0usize;
    let mut bv = values[0];
    for (i, &v) in values.iter().enumerate().skip(1) {
        if v > bv {
            bv = v;
            best = i;
        }
    }
    best
}

/// Pearson correlation coefficient between two equal-length samples.
/// Returns 0.0 when either sample has zero variance.
pub fn pearson(xs: &[f32], ys: &[f32]) -> f32 {
    assert_eq!(xs.len(), ys.len());
    let n = xs.len();
    if n < 2 {
        return 0.0;
    }
    let nf = n as f64;
    let mx = xs.iter().map(|&v| v as f64).sum::<f64>() / nf;
    let my = ys.iter().map(|&v| v as f64).sum::<f64>() / nf;
    let (mut sxy, mut sxx, mut syy) = (0.0f64, 0.0f64, 0.0f64);
    for i in 0..n {
        let dx = xs[i] as f64 - mx;
        let dy = ys[i] as f64 - my;
        sxy += dx * dy;
        sxx += dx * dx;
        syy += dy * dy;
    }
    if sxx == 0.0 || syy == 0.0 {
        return 0.0;
    }
    (sxy / (sxx.sqrt() * syy.sqrt())) as f32
}

/// ‖proj_r r'‖² = ⟨r̂, r'⟩² — the Theorem 3.1 parallelism penalty term.
#[inline]
pub fn parallel_component_sq(r_hat: &[f32], r_prime: &[f32]) -> f32 {
    let p = dot(r_hat, r_prime);
    p * p
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_basic() {
        assert_eq!(dot(&[1.0, 2.0, 3.0], &[4.0, 5.0, 6.0]), 32.0);
        assert_eq!(dot(&[], &[]), 0.0);
        // length > unroll factor
        let a: Vec<f32> = (0..13).map(|i| i as f32).collect();
        let b = vec![2.0f32; 13];
        assert_eq!(dot(&a, &b), 2.0 * (0..13).sum::<i32>() as f32);
    }

    #[test]
    fn dot_i8_matches_widened_dot() {
        let q: Vec<f32> = (0..13).map(|i| 0.25 * i as f32 - 1.0).collect();
        let rec: Vec<i8> = (0..13).map(|i| (i * 17 % 255) as u8 as i8).collect();
        let widened: Vec<f32> = rec.iter().map(|&v| v as f32).collect();
        assert!((dot_i8(&q, &rec) - dot(&q, &widened)).abs() < 1e-4);
        assert_eq!(dot_i8(&[], &[]), 0.0);
    }

    #[test]
    fn squared_l2_matches_dot_expansion() {
        let a = [1.0f32, -2.0, 0.5, 3.0, 1.0];
        let b = [0.0f32, 1.0, 0.5, -1.0, 2.0];
        let direct = squared_l2(&a, &b);
        let expanded = dot(&a, &a) - 2.0 * dot(&a, &b) + dot(&b, &b);
        assert!((direct - expanded).abs() < 1e-5);
    }

    #[test]
    fn normalize_and_cosine() {
        let mut v = vec![3.0f32, 4.0];
        let n = normalize(&mut v);
        assert!((n - 5.0).abs() < 1e-6);
        assert!((norm(&v) - 1.0).abs() < 1e-6);
        assert!((cosine(&[1.0, 0.0], &[0.0, 2.0])).abs() < 1e-6);
        assert!((cosine(&[1.0, 1.0], &[2.0, 2.0]) - 1.0).abs() < 1e-6);
        // zero vector stays zero, cosine defined as 0
        let mut z = vec![0.0f32; 3];
        assert_eq!(normalize(&mut z), 0.0);
        assert_eq!(cosine(&z, &[1.0, 2.0, 3.0]), 0.0);
    }

    #[test]
    fn argminmax() {
        let v = [3.0f32, -1.0, 7.0, -1.0, 2.0];
        assert_eq!(argmin(&v), 1); // first min wins
        assert_eq!(argmax(&v), 2);
    }

    #[test]
    fn pearson_limits() {
        let x: Vec<f32> = (0..100).map(|i| i as f32).collect();
        let y: Vec<f32> = x.iter().map(|v| 2.0 * v + 1.0).collect();
        assert!((pearson(&x, &y) - 1.0).abs() < 1e-6);
        let yneg: Vec<f32> = x.iter().map(|v| -v).collect();
        assert!((pearson(&x, &yneg) + 1.0).abs() < 1e-6);
        let flat = vec![1.0f32; 100];
        assert_eq!(pearson(&x, &flat), 0.0);
    }

    #[test]
    fn axpy_sub_scale() {
        let mut a = vec![1.0f32, 2.0];
        axpy(0.5, &[2.0, 4.0], &mut a);
        assert_eq!(a, vec![2.0, 4.0]);
        let mut out = vec![0.0f32; 2];
        sub(&[3.0, 3.0], &[1.0, 2.0], &mut out);
        assert_eq!(out, vec![2.0, 1.0]);
        scale(&mut out, 2.0);
        assert_eq!(out, vec![4.0, 2.0]);
    }

    #[test]
    fn parallel_component() {
        let r_hat = [1.0f32, 0.0];
        assert_eq!(parallel_component_sq(&r_hat, &[3.0, 4.0]), 9.0);
        assert_eq!(parallel_component_sq(&r_hat, &[0.0, 4.0]), 0.0);
    }
}
