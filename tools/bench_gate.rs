//! `bench_gate` — the CI bench-regression gate.
//!
//! Diffs the bench JSON reports the CI bench job just produced
//! (`BENCH_*.json`) against the committed baselines
//! (`BENCH_*.baseline.json`) and fails the job when a tracked metric
//! regresses:
//!
//! * throughput metrics (`*_qps`, `*_per_sec`, `speedup*`, `retention`):
//!   fail when current < baseline by more than `--tolerance` (default
//!   15%);
//! * latency/time metrics (`*_ns*`, `*_us`, `*_ms`, `*_secs`, `p50`,
//!   `p99`): fail when current > baseline by more than `--tolerance`;
//! * recall metrics (`*recall*`): fail on any absolute drop greater
//!   than `--recall-drop` (default 0.01) — recall is seeded and
//!   deterministic, so the bar is much tighter than for wall-clock
//!   metrics;
//! * allocation counts (`*allocs*`): lower is better, and a **zero**
//!   baseline is a contract, not a measurement — any allocation at all
//!   fails, with no relative tolerance (0 → 1 is a broken zero-alloc
//!   hot path, not a 15% wobble).
//!
//! Counters, shapes, and config echoes (`n`, `dim`, `quick`, …) are not
//! gated. Metrics are matched by their path through the report, with
//! array elements keyed by a discriminator field (`list_len`, `shards`,
//! `segments`, `config`, `publish_coalesce`) so reordering does not
//! misalign the diff.
//!
//! A baseline containing `"pending": true` is a **bootstrap** baseline:
//! the gate reports the current numbers, passes, and asks for the
//! refreshed baseline (uploaded as a CI artifact) to be committed —
//! this is how a baseline is first materialized on the actual CI
//! hardware instead of a developer laptop. `--update` rewrites the
//! baseline files from the current reports locally.
//!
//! A per-metric summary table is printed to stdout and appended to
//! `$GITHUB_STEP_SUMMARY` when that file is set (the GitHub Actions
//! job-summary protocol).
//!
//! Usage:
//!   bench_gate [--tolerance 0.15] [--recall-drop 0.01] [--update] \
//!       <name> <baseline.json> <current.json> [<name> <b> <c> …]

use std::io::Write as _;

use soar_ann::util::json::Value;

/// How a metric is compared.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum MetricKind {
    /// Throughput-like: higher is better, relative tolerance.
    HigherBetter,
    /// Latency/time-like: lower is better, relative tolerance.
    LowerBetter,
    /// Recall: higher is better, absolute-drop tolerance.
    Recall,
    /// Allocator-call counts: lower is better; a zero baseline admits
    /// no allocation at all (the zero-alloc hot-path contract).
    Allocs,
}

/// Classify a metric by the last path segment (the leaf key). Returns
/// `None` for numbers that are not performance metrics (counts, shapes,
/// config echoes).
fn classify(key: &str) -> Option<MetricKind> {
    let k = key.to_ascii_lowercase();
    if k.contains("recall") {
        return Some(MetricKind::Recall);
    }
    if k.contains("allocs") {
        return Some(MetricKind::Allocs);
    }
    // Streamed-bytes volume: lower is better (the grouped executor's
    // whole point is shrinking bytes/query). Checked before the generic
    // rules so a future `bytes_streamed_per_sec` spelling can't flip it.
    if k.contains("bytes_streamed") {
        return Some(MetricKind::LowerBetter);
    }
    if k.ends_with("_qps")
        || k == "qps"
        || k.starts_with("qps_")
        || k.contains("per_sec")
        || k.contains("speedup")
        || k.contains("retention")
    {
        return Some(MetricKind::HigherBetter);
    }
    if k.contains("_ns")
        || k.ends_with("_us")
        || k.ends_with("_ms")
        || k.ends_with("_secs")
        || k.contains("latency")
        || k.contains("p50")
        || k.contains("p99")
    {
        return Some(MetricKind::LowerBetter);
    }
    None
}

/// Array elements are labeled by the first discriminator field they
/// carry, so baseline/current rows align even if the array is reordered
/// or grows.
const DISCRIMINATORS: &[&str] = &[
    "list_len",
    "shards",
    "segments",
    "config",
    "publish_coalesce",
    "batch",
    "bench",
];

fn element_label(v: &Value, index: usize) -> String {
    for d in DISCRIMINATORS {
        if let Some(val) = v.get(d) {
            if let Some(s) = val.as_str() {
                return format!("{d}={s}");
            }
            if let Some(n) = val.as_f64() {
                return format!("{d}={n}");
            }
        }
    }
    format!("[{index}]")
}

/// Flatten a report into `(path, leaf_key, value)` numeric leaves.
fn flatten(v: &Value, path: &str, out: &mut Vec<(String, String, f64)>) {
    match v {
        Value::Num(n) => {
            let key = path.rsplit('/').next().unwrap_or(path).to_string();
            out.push((path.to_string(), key, *n));
        }
        Value::Obj(m) => {
            for (k, child) in m {
                let p = if path.is_empty() {
                    k.clone()
                } else {
                    format!("{path}/{k}")
                };
                flatten(child, &p, out);
            }
        }
        Value::Arr(items) => {
            for (i, child) in items.iter().enumerate() {
                let label = element_label(child, i);
                let p = if path.is_empty() {
                    label.clone()
                } else {
                    format!("{path}/{label}")
                };
                flatten(child, &p, out);
            }
        }
        _ => {}
    }
}

/// One compared metric, ready for the summary table.
struct Row {
    suite: String,
    path: String,
    kind: MetricKind,
    baseline: f64,
    current: f64,
    /// Signed relative change, improvement-positive (throughput up /
    /// latency down / recall up ⇒ positive).
    delta: f64,
    failed: bool,
}

impl Row {
    fn status(&self) -> &'static str {
        if self.failed {
            "REGRESSED"
        } else if self.delta > 0.0 {
            "ok (improved)"
        } else {
            "ok"
        }
    }
}

fn compare(
    suite: &str,
    baseline: &Value,
    current: &Value,
    tolerance: f64,
    recall_drop: f64,
    rows: &mut Vec<Row>,
    missing: &mut Vec<String>,
) {
    let mut base_leaves = Vec::new();
    flatten(baseline, "", &mut base_leaves);
    let mut cur_leaves = Vec::new();
    flatten(current, "", &mut cur_leaves);
    for (path, key, base) in &base_leaves {
        let Some(kind) = classify(key) else { continue };
        let Some((_, _, cur)) = cur_leaves.iter().find(|(p, _, _)| p == path) else {
            missing.push(format!("{suite}:{path}"));
            continue;
        };
        let cur = *cur;
        let (delta, failed) = match kind {
            MetricKind::Recall => {
                let drop = base - cur;
                (cur - base, drop > recall_drop)
            }
            MetricKind::HigherBetter => {
                let rel = if base.abs() > f64::EPSILON {
                    (cur - base) / base
                } else {
                    0.0
                };
                (rel, rel < -tolerance)
            }
            MetricKind::LowerBetter => {
                let rel = if base.abs() > f64::EPSILON {
                    (cur - base) / base
                } else {
                    0.0
                };
                // improvement-positive: latency going down is good
                (-rel, rel > tolerance)
            }
            MetricKind::Allocs => {
                if base.abs() <= f64::EPSILON {
                    // A zero baseline is absolute: one allocation breaks
                    // the contract (relative tolerance from 0 would pass
                    // anything).
                    (if cur > 0.0 { -1.0 } else { 0.0 }, cur > 0.0)
                } else {
                    let rel = (cur - base) / base;
                    (-rel, rel > tolerance)
                }
            }
        };
        rows.push(Row {
            suite: suite.to_string(),
            path: path.clone(),
            kind,
            baseline: *base,
            current: cur,
            delta,
            failed,
        });
    }
}

fn fmt_value(kind: MetricKind, v: f64) -> String {
    match kind {
        MetricKind::Recall => format!("{v:.4}"),
        _ => {
            if v.abs() >= 1000.0 {
                format!("{v:.0}")
            } else {
                format!("{v:.3}")
            }
        }
    }
}

fn summary_table(rows: &[Row], missing: &[String], bootstraps: &[String]) -> String {
    let mut out = String::new();
    out.push_str("## Bench regression gate\n\n");
    if !bootstraps.is_empty() {
        out.push_str(&format!(
            "⚠️ bootstrap baselines (no comparison run): {} — commit the \
             `bench-baselines` artifact from this run to arm the gate.\n\n",
            bootstraps.join(", ")
        ));
    }
    if rows.is_empty() && bootstraps.is_empty() {
        out.push_str("no tracked metrics found.\n");
        return out;
    }
    if !rows.is_empty() {
        out.push_str("| suite | metric | baseline | current | Δ | status |\n");
        out.push_str("|---|---|---:|---:|---:|---|\n");
        for r in rows {
            out.push_str(&format!(
                "| {} | `{}` | {} | {} | {:+.1}% | {} |\n",
                r.suite,
                r.path,
                fmt_value(r.kind, r.baseline),
                fmt_value(r.kind, r.current),
                r.delta * 100.0,
                r.status()
            ));
        }
    }
    if !missing.is_empty() {
        out.push_str(&format!(
            "\n❌ metrics in the baseline but absent from the current report \
             (renamed bench? refresh the baseline explicitly — a vanished \
             metric must not silently disarm its gate): {}\n",
            missing.join(", ")
        ));
    }
    out
}

fn usage() -> ! {
    eprintln!(
        "usage: bench_gate [--tolerance 0.15] [--recall-drop 0.01] [--update] \
         <name> <baseline.json> <current.json> [<name> <baseline> <current> ...]"
    );
    std::process::exit(2);
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut tolerance = 0.15f64;
    let mut recall_drop = 0.01f64;
    let mut update = false;
    let mut triples: Vec<String> = Vec::new();
    let mut it = args.into_iter();
    while let Some(a) = it.next() {
        match a.as_str() {
            "--tolerance" => {
                tolerance = it
                    .next()
                    .and_then(|v| v.parse().ok())
                    .unwrap_or_else(|| usage());
            }
            "--recall-drop" => {
                recall_drop = it
                    .next()
                    .and_then(|v| v.parse().ok())
                    .unwrap_or_else(|| usage());
            }
            "--update" => update = true,
            _ => triples.push(a),
        }
    }
    if triples.is_empty() || triples.len() % 3 != 0 {
        usage();
    }

    let mut rows: Vec<Row> = Vec::new();
    let mut missing: Vec<String> = Vec::new();
    let mut bootstraps: Vec<String> = Vec::new();
    let mut hard_error = false;
    for chunk in triples.chunks(3) {
        let (suite, base_path, cur_path) = (&chunk[0], &chunk[1], &chunk[2]);
        let current = match std::fs::read_to_string(cur_path).map_err(|e| e.to_string()) {
            Ok(text) => match Value::parse(&text) {
                Ok(v) => v,
                Err(e) => {
                    eprintln!("{suite}: cannot parse current report {cur_path}: {e}");
                    hard_error = true;
                    continue;
                }
            },
            Err(e) => {
                eprintln!("{suite}: cannot read current report {cur_path}: {e}");
                hard_error = true;
                continue;
            }
        };
        if update {
            if let Err(e) = std::fs::write(base_path, current.to_json_pretty()) {
                eprintln!("{suite}: cannot update baseline {base_path}: {e}");
                hard_error = true;
            } else {
                println!("{suite}: baseline {base_path} updated from {cur_path}");
            }
            continue;
        }
        let baseline = match std::fs::read_to_string(base_path).map_err(|e| e.to_string()) {
            Ok(text) => match Value::parse(&text) {
                Ok(v) => v,
                Err(e) => {
                    eprintln!("{suite}: cannot parse baseline {base_path}: {e}");
                    hard_error = true;
                    continue;
                }
            },
            Err(e) => {
                eprintln!("{suite}: cannot read baseline {base_path}: {e}");
                hard_error = true;
                continue;
            }
        };
        if baseline.get("pending").and_then(|v| v.as_bool()) == Some(true) {
            bootstraps.push(suite.clone());
            continue;
        }
        compare(
            suite,
            &baseline,
            &current,
            tolerance,
            recall_drop,
            &mut rows,
            &mut missing,
        );
    }
    if update {
        std::process::exit(if hard_error { 1 } else { 0 });
    }

    let table = summary_table(&rows, &missing, &bootstraps);
    println!("{table}");
    if let Ok(path) = std::env::var("GITHUB_STEP_SUMMARY") {
        if let Ok(mut f) = std::fs::OpenOptions::new().append(true).create(true).open(&path) {
            let _ = writeln!(f, "{table}");
        }
    }

    let regressed: Vec<&Row> = rows.iter().filter(|r| r.failed).collect();
    if !regressed.is_empty() {
        eprintln!("bench gate FAILED: {} metric(s) regressed", regressed.len());
        for r in &regressed {
            eprintln!(
                "  {}:{} {} → {} ({:+.1}%)",
                r.suite,
                r.path,
                fmt_value(r.kind, r.baseline),
                fmt_value(r.kind, r.current),
                r.delta * 100.0
            );
        }
        std::process::exit(1);
    }
    // A gated metric that vanished from the current report is a failure
    // too: renaming or dropping a bench must come with an explicit
    // baseline refresh, not a silently disarmed gate.
    if !missing.is_empty() {
        eprintln!(
            "bench gate FAILED: {} baseline metric(s) missing from the current \
             report: {}",
            missing.len(),
            missing.join(", ")
        );
        std::process::exit(1);
    }
    if hard_error {
        std::process::exit(1);
    }
    println!(
        "bench gate passed: {} metric(s) within tolerance ({} bootstrap suite(s))",
        rows.len(),
        bootstraps.len()
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn classification_covers_the_report_vocabulary() {
        assert_eq!(classify("search_qps"), Some(MetricKind::HigherBetter));
        assert_eq!(classify("batch_qps"), Some(MetricKind::HigherBetter));
        assert_eq!(classify("qps_idle"), Some(MetricKind::HigherBetter));
        assert_eq!(classify("qps_retention"), Some(MetricKind::HigherBetter));
        assert_eq!(
            classify("blocked_points_per_sec"),
            Some(MetricKind::HigherBetter)
        );
        assert_eq!(
            classify("speedup_blocked_vs_scalar"),
            Some(MetricKind::HigherBetter)
        );
        assert_eq!(
            classify("scalar_ns_per_candidate"),
            Some(MetricKind::LowerBetter)
        );
        assert_eq!(classify("upsert_p50_us"), Some(MetricKind::LowerBetter));
        assert_eq!(classify("upsert_p99_us"), Some(MetricKind::LowerBetter));
        assert_eq!(classify("median_ns"), Some(MetricKind::LowerBetter));
        assert_eq!(classify("retrain_secs"), Some(MetricKind::LowerBetter));
        assert_eq!(
            classify("auto_drift_to_install_secs"),
            Some(MetricKind::LowerBetter)
        );
        assert_eq!(classify("recall_after_retrain"), Some(MetricKind::Recall));
        assert_eq!(classify("auto_recall_recovered"), Some(MetricKind::Recall));
        assert_eq!(classify("allocs_per_query"), Some(MetricKind::Allocs));
        assert_eq!(classify("allocs_per_batch"), Some(MetricKind::Allocs));
        assert_eq!(classify("single_query_p50_us"), Some(MetricKind::LowerBetter));
        assert_eq!(
            classify("speedup_batch_vs_serial"),
            Some(MetricKind::HigherBetter)
        );
        assert_eq!(classify("serial_loop_qps"), Some(MetricKind::HigherBetter));
        assert_eq!(
            classify("code_bytes_streamed_per_query"),
            Some(MetricKind::LowerBetter)
        );
        // `batch` itself is a discriminator, not a metric.
        assert_eq!(classify("batch"), None);
        // Not gated: counts, shapes, config echoes.
        assert_eq!(classify("n"), None);
        assert_eq!(classify("dim"), None);
        assert_eq!(classify("rows"), None);
        assert_eq!(classify("auto_retrains"), None);
        assert_eq!(classify("background_retrains"), None);
        assert_eq!(classify("search_iters"), None);
        assert_eq!(classify("upsert_ops"), None);
    }

    fn report(qps: f64, p99: f64, recall: f64) -> Value {
        Value::parse(&format!(
            "{{\"bench\":\"t\",\"n\":100,\"per_shard\":[{{\"shards\":1,\
             \"search_qps\":{qps},\"upsert_p99_us\":{p99}}}],\
             \"recall_after_retrain\":{recall}}}"
        ))
        .unwrap()
    }

    fn run_compare(base: &Value, cur: &Value) -> (Vec<Row>, Vec<String>) {
        let mut rows = Vec::new();
        let mut missing = Vec::new();
        compare("t", base, cur, 0.15, 0.01, &mut rows, &mut missing);
        (rows, missing)
    }

    #[test]
    fn within_tolerance_passes_and_regressions_fail() {
        let base = report(1000.0, 50.0, 0.90);
        // 10% QPS dip, 10% latency rise, recall drop of 0.005: all inside.
        let ok = report(900.0, 55.0, 0.895);
        let (rows, missing) = run_compare(&base, &ok);
        assert!(missing.is_empty());
        assert_eq!(rows.len(), 3);
        assert!(rows.iter().all(|r| !r.failed), "within-tolerance must pass");
        // 20% QPS regression fails; others keep passing.
        let qps_bad = report(800.0, 50.0, 0.90);
        let (rows, _) = run_compare(&base, &qps_bad);
        assert_eq!(rows.iter().filter(|r| r.failed).count(), 1);
        assert!(rows.iter().any(|r| r.failed && r.path.contains("search_qps")));
        // 20% latency regression fails.
        let lat_bad = report(1000.0, 60.0, 0.90);
        let (rows, _) = run_compare(&base, &lat_bad);
        assert!(rows.iter().any(|r| r.failed && r.path.contains("p99")));
        // recall drop of 0.02 fails even though it is < 15% relative.
        let recall_bad = report(1000.0, 50.0, 0.88);
        let (rows, _) = run_compare(&base, &recall_bad);
        assert!(rows.iter().any(|r| r.failed && r.path.contains("recall")));
        // Improvements never fail.
        let better = report(2000.0, 10.0, 0.99);
        let (rows, _) = run_compare(&base, &better);
        assert!(rows.iter().all(|r| !r.failed));
        assert!(rows.iter().all(|r| r.delta > 0.0));
    }

    #[test]
    fn zero_alloc_baseline_fails_on_any_allocation() {
        let base = Value::parse("{\"allocs_per_query\":0}").unwrap();
        let clean = Value::parse("{\"allocs_per_query\":0}").unwrap();
        let (rows, _) = run_compare(&base, &clean);
        assert_eq!(rows.len(), 1);
        assert!(!rows[0].failed, "0 → 0 must pass");
        // A single allocation breaks the contract — relative tolerance
        // from a zero baseline must not wave it through.
        let dirty = Value::parse("{\"allocs_per_query\":1}").unwrap();
        let (rows, _) = run_compare(&base, &dirty);
        assert!(rows[0].failed, "0 → 1 must fail the gate");
        // Nonzero baselines fall back to relative tolerance.
        let base = Value::parse("{\"allocs_per_query\":100}").unwrap();
        let ok = Value::parse("{\"allocs_per_query\":110}").unwrap();
        let (rows, _) = run_compare(&base, &ok);
        assert!(!rows[0].failed, "10% rise is inside tolerance");
        let bad = Value::parse("{\"allocs_per_query\":130}").unwrap();
        let (rows, _) = run_compare(&base, &bad);
        assert!(rows[0].failed, "30% rise regresses");
    }

    #[test]
    fn array_rows_align_by_discriminator_and_missing_is_reported() {
        let base = Value::parse(
            "{\"per_shard\":[{\"shards\":1,\"search_qps\":1000},\
             {\"shards\":4,\"search_qps\":3000}]}",
        )
        .unwrap();
        // Reordered array + one shard count gone.
        let cur = Value::parse("{\"per_shard\":[{\"shards\":4,\"search_qps\":2950}]}").unwrap();
        let (rows, missing) = run_compare(&base, &cur);
        assert_eq!(rows.len(), 1);
        assert!(rows[0].path.contains("shards=4"));
        assert!(!rows[0].failed, "2950 vs 3000 is within 15%");
        assert_eq!(missing.len(), 1);
        assert!(missing[0].contains("shards=1"));
    }

    #[test]
    fn summary_table_mentions_every_row() {
        let base = report(1000.0, 50.0, 0.90);
        let cur = report(700.0, 50.0, 0.90);
        let (rows, missing) = run_compare(&base, &cur);
        let table = summary_table(&rows, &missing, &["hotpath".to_string()]);
        assert!(table.contains("search_qps"));
        assert!(table.contains("REGRESSED"));
        assert!(table.contains("bootstrap"));
        assert!(table.contains("| suite | metric |"));
    }
}
