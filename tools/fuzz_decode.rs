//! `fuzz_decode` — deterministic structured fuzzing of every on-disk
//! decoder: v1 index files, v2/v4 snapshots (plain and durable-footer),
//! v3 collection manifests, and WAL segments (rotated and tail).
//!
//! Dependency-free by design: corpora are generated in-process by the
//! crate's own savers, then mutated with the in-tree seeded PRNG
//! ([`soar_ann::linalg::Rng`]) — byte/bit flips, truncations,
//! extensions, length-field corruption (biased toward huge u32s),
//! section swaps, and range zeroing. Every mutated artifact is fed to
//! the matching loader under `catch_unwind`.
//!
//! Pass criteria per case:
//!
//! * the loader returns `Ok` (mutation survived verification — e.g. a
//!   no-op flip) or a clean `Err` — **never a panic**;
//! * a snapshot that loads `Ok` still satisfies `check_invariants()`;
//! * no single allocation exceeds 1 GiB: a corrupted length field must
//!   be rejected by plausibility gates *before* `Vec::with_capacity`,
//!   not discovered by the OOM killer. The capped global allocator
//!   turns such a request into an immediate abort (the fuzzer's one
//!   non-catchable failure mode — CI treats the non-zero exit the same
//!   as a panic).
//!
//! The error-variant distribution is printed at the end; `Corrupt`
//! dominates by construction (checksums), with `Serialize`/`Io` from
//! header/truncation damage.
//!
//! Usage: `fuzz_decode [--cases N] [--seed S] [--verbose]`
//! (defaults: 2000 cases, seed 0x50AF; CI runs 12000). Failures print
//! the (corpus, case, seed) triple — rerun with the same `--seed` and
//! `--verbose` to replay.

use std::alloc::{GlobalAlloc, Layout, System};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::Path;
use std::sync::Arc;

use soar_ann::config::{CollectionConfig, IndexConfig, MutableConfig};
use soar_ann::data::synthetic::SyntheticConfig;
use soar_ann::error::Error;
use soar_ann::index::serialize::{
    load_collection_parts, load_index, load_snapshot, save_collection_durable, save_index,
    save_snapshot_durable, save_snapshot_versioned, COLLECTION_MANIFEST,
    COLLECTION_MANIFEST_BACKUP,
};
use soar_ann::index::wal::ShardWal;
use soar_ann::index::{build_index, CollectionSnapshot, IndexSnapshot, MutableIndex};
use soar_ann::linalg::Rng;
use soar_ann::runtime::Engine;
use soar_ann::util::fs::RealFs;
use soar_ann::util::tempdir::TempDir;

/// Largest single allocation a decoder may request while loading a
/// corpus-sized (~tens of KB) artifact. Generous: legitimate loads stay
/// under a few MB; only a length field interpreted without a
/// plausibility gate can get here.
const ALLOC_CAP: usize = 1 << 30;

struct CappedAlloc;

// SAFETY: defers entirely to `System` for every in-cap request; over-cap
// requests return null, which the caller's `handle_alloc_error` turns
// into an abort (the intended failure report).
unsafe impl GlobalAlloc for CappedAlloc {
    // SAFETY: caller upholds GlobalAlloc's contract; forwarded unchanged.
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        if layout.size() > ALLOC_CAP {
            return std::ptr::null_mut();
        }
        System.alloc(layout)
    }
    // SAFETY: `ptr` came from this allocator with `layout`; forwarded.
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
    // SAFETY: same contract as `alloc`; forwarded unchanged.
    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        if layout.size() > ALLOC_CAP {
            return std::ptr::null_mut();
        }
        System.alloc_zeroed(layout)
    }
    // SAFETY: `ptr` is a live allocation of `layout`; forwarded.
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        if new_size > ALLOC_CAP {
            return std::ptr::null_mut();
        }
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static ALLOC: CappedAlloc = CappedAlloc;

/// Which loader a corpus exercises.
#[derive(Clone, Copy, PartialEq)]
enum Kind {
    V1Index,
    Snapshot,
    Manifest,
    Wal,
}

/// One fuzz target: pristine bytes for the mutated file, plus any
/// sibling files the loader needs (shard bodies, the other WAL segment),
/// re-written pristine before every case because some loaders repair or
/// quarantine files in place.
struct Corpus {
    name: &'static str,
    kind: Kind,
    /// File the mutated bytes are written to, relative to the case dir.
    target: &'static str,
    pristine: Vec<u8>,
    /// (relative name, bytes) written pristine before each case.
    siblings: Vec<(String, Vec<u8>)>,
}

/// Small but structurally complete fixture: sealed base segments plus a
/// delta with an update and a tombstone, so every snapshot section
/// (postings, codes, delta rows, tombstones, model table) is populated.
fn fixture_snapshot(engine: &Arc<Engine>, seed: u64) -> Arc<IndexSnapshot> {
    let ds = SyntheticConfig::glove_like(160, 8, 8, seed).generate();
    let cfg = IndexConfig {
        num_partitions: 8,
        ..Default::default()
    };
    let base = build_index(engine, &ds.data, &cfg).expect("fixture build");
    let m = MutableIndex::from_index(base, engine.clone(), MutableConfig::default())
        .expect("fixture mutable");
    let mut rng = Rng::new(seed ^ 0xF1B);
    for id in 0..4u32 {
        let mut v = ds.data.row(id as usize).to_vec();
        for x in v.iter_mut() {
            *x += 0.05 * rng.next_gaussian();
        }
        soar_ann::linalg::normalize(&mut v);
        m.upsert(1000 + id, &v).expect("fixture upsert");
    }
    m.delete(3).expect("fixture delete");
    m.snapshot()
}

fn read(path: &Path) -> Vec<u8> {
    std::fs::read(path).unwrap_or_else(|e| panic!("read corpus {}: {e}", path.display()))
}

/// Build every corpus once, via the real savers, in a scratch dir.
fn build_corpora(scratch: &Path) -> Vec<Corpus> {
    let engine = Arc::new(Engine::cpu());
    let snap = fixture_snapshot(&engine, 7);
    let snap2 = fixture_snapshot(&engine, 11);
    let mut corpora = Vec::new();

    // v1 index file (legacy single-segment format).
    {
        let ds = SyntheticConfig::glove_like(160, 8, 8, 5).generate();
        let cfg = IndexConfig {
            num_partitions: 8,
            ..Default::default()
        };
        let index = build_index(&engine, &ds.data, &cfg).expect("v1 build");
        let path = scratch.join("v1.soar");
        save_index(&index, &path).expect("save v1");
        corpora.push(Corpus {
            name: "v1-index",
            kind: Kind::V1Index,
            target: "index.soar",
            pristine: read(&path),
            siblings: Vec::new(),
        });
    }
    // v2 (legacy segmented) and v4 (model-table) snapshots, plus the
    // durable-footer v4 layout.
    for (name, version) in [("v2-snapshot", 2u32), ("v4-snapshot", 4u32)] {
        let path = scratch.join(format!("{name}.soar"));
        save_snapshot_versioned(&snap, &path, version).expect("save snapshot");
        corpora.push(Corpus {
            name,
            kind: Kind::Snapshot,
            target: "snap.soar",
            pristine: read(&path),
            siblings: Vec::new(),
        });
    }
    {
        let path = scratch.join("v4d.soar");
        save_snapshot_durable(&snap, &path, &RealFs).expect("save durable snapshot");
        corpora.push(Corpus {
            name: "v4-durable-snapshot",
            kind: Kind::Snapshot,
            target: "snap.soar",
            pristine: read(&path),
            siblings: Vec::new(),
        });
    }
    // v3 collection manifest + two shard files. Only the manifest is
    // mutated; shards are pristine siblings. The backup manifest is not
    // written into case dirs, so recovery cannot silently mask a broken
    // primary.
    {
        let dir = scratch.join("coll");
        std::fs::create_dir_all(&dir).expect("mkdir coll");
        let cs = CollectionSnapshot {
            shards: vec![snap.clone(), snap2.clone()],
        };
        save_collection_durable(&cs, &CollectionConfig::default(), &dir, &RealFs)
            .expect("save collection");
        let mut siblings = Vec::new();
        for entry in std::fs::read_dir(&dir).expect("ls coll") {
            let p = entry.expect("ls coll").path();
            let fname = p.file_name().unwrap().to_string_lossy().into_owned();
            if fname == COLLECTION_MANIFEST || fname == COLLECTION_MANIFEST_BACKUP {
                continue;
            }
            siblings.push((fname, read(&p)));
        }
        corpora.push(Corpus {
            name: "v3-manifest",
            kind: Kind::Manifest,
            target: COLLECTION_MANIFEST,
            pristine: read(&dir.join(COLLECTION_MANIFEST)),
            siblings,
        });
    }
    // WAL: two segments (one rotated + sealed, one live tail). Rotated
    // segments get the strict no-torn-tail treatment; the tail tolerates
    // a torn final record but nothing else.
    {
        let dir = scratch.join("wal");
        std::fs::create_dir_all(&dir).expect("mkdir wal");
        let (mut wal, _) = ShardWal::open(&dir, Arc::new(RealFs)).expect("wal open");
        let mut rng = Rng::new(13);
        let mut vec8 = [0f32; 8];
        for id in 0..5u32 {
            rng.fill_gaussian(&mut vec8);
            wal.append_upsert(id, &vec8).expect("wal append");
        }
        wal.append_delete(2).expect("wal delete");
        wal.sync().expect("wal sync");
        wal.rotate().expect("wal rotate");
        for id in 5..8u32 {
            rng.fill_gaussian(&mut vec8);
            wal.append_upsert(id, &vec8).expect("wal append");
        }
        wal.sync().expect("wal sync");
        drop(wal);
        let mut names: Vec<String> = std::fs::read_dir(&dir)
            .expect("ls wal")
            .map(|e| e.expect("ls wal").file_name().to_string_lossy().into_owned())
            .filter(|n| n.starts_with("wal-"))
            .collect();
        names.sort();
        assert!(names.len() >= 2, "expected ≥2 wal segments, got {names:?}");
        let seg_bytes: Vec<(String, Vec<u8>)> = names
            .iter()
            .map(|n| (n.clone(), read(&dir.join(n))))
            .collect();
        for (mutate_idx, cname) in [(0usize, "wal-rotated-segment"), (1, "wal-tail-segment")] {
            let target: &'static str = Box::leak(seg_bytes[mutate_idx].0.clone().into_boxed_str());
            let siblings = seg_bytes
                .iter()
                .enumerate()
                .filter(|(i, _)| *i != mutate_idx)
                .map(|(_, (n, b))| (n.clone(), b.clone()))
                .collect();
            corpora.push(Corpus {
                name: cname,
                kind: Kind::Wal,
                target,
                pristine: seg_bytes[mutate_idx].1.clone(),
                siblings,
            });
        }
    }
    corpora
}

/// Apply one seeded structured mutation to `bytes`.
fn mutate(bytes: &mut Vec<u8>, rng: &mut Rng) {
    let pick = |rng: &mut Rng, len: usize| rng.next_below(len.max(1) as u32) as usize;
    match rng.next_below(6) {
        // Bit/byte flips.
        0 => {
            let flips = 1 + rng.next_below(8) as usize;
            for _ in 0..flips {
                if bytes.is_empty() {
                    break;
                }
                let i = pick(rng, bytes.len());
                bytes[i] ^= 1 << rng.next_below(8);
            }
        }
        // Truncation (framing / torn-tail handling).
        1 => {
            let at = pick(rng, bytes.len() + 1);
            bytes.truncate(at);
        }
        // Extension with random garbage (trailing-byte handling).
        2 => {
            let extra = 1 + rng.next_below(64) as usize;
            for _ in 0..extra {
                bytes.push(rng.next_u32() as u8);
            }
        }
        // Length-field corruption: overwrite 4 bytes with a value biased
        // toward overflow-provoking magnitudes.
        3 => {
            if bytes.len() >= 4 {
                let i = pick(rng, bytes.len() - 3);
                let v: u32 = match rng.next_below(5) {
                    0 => u32::MAX,
                    1 => i32::MAX as u32,
                    2 => u32::MAX - rng.next_below(8),
                    3 => 1 << (24 + rng.next_below(8)),
                    _ => rng.next_u32(),
                };
                bytes[i..i + 4].copy_from_slice(&v.to_le_bytes());
            }
        }
        // Section swap: exchange two disjoint ranges.
        4 => {
            if bytes.len() >= 8 {
                let max_w = (bytes.len() / 2).min(256);
                let w = 1 + pick(rng, max_w);
                let a = pick(rng, bytes.len() - 2 * w + 1);
                let b = a + w + pick(rng, bytes.len() - a - 2 * w + 1);
                for k in 0..w {
                    bytes.swap(a + k, b + k);
                }
            }
        }
        // Zero a range (simulates sparse-file holes / partial writes).
        _ => {
            if !bytes.is_empty() {
                let a = pick(rng, bytes.len());
                let w = 1 + pick(rng, (bytes.len() - a).min(512));
                for x in &mut bytes[a..a + w] {
                    *x = 0;
                }
            }
        }
    }
}

fn variant_name(e: &Error) -> &'static str {
    match e {
        Error::Config(_) => "Config",
        Error::Shape(_) => "Shape",
        Error::Serialize(_) => "Serialize",
        Error::Io(_) => "Io",
        Error::Corrupt { .. } => "Corrupt",
        Error::Runtime(_) => "Runtime",
        Error::Coordinator(_) => "Coordinator",
    }
}

/// Run one loader over the case dir. Returns the outcome label, or
/// `Err(finding)` for a panic or an `Ok` that fails invariant checks.
fn run_loader(kind: Kind, dir: &Path, target: &Path) -> Result<&'static str, String> {
    let outcome = catch_unwind(AssertUnwindSafe(|| match kind {
        Kind::V1Index => load_index(target).map(|_| ()),
        Kind::Snapshot => load_snapshot(target).and_then(|s| s.check_invariants()),
        Kind::Manifest => load_collection_parts(dir).and_then(|(shards, _)| {
            for s in &shards {
                s.check_invariants()?;
            }
            Ok(())
        }),
        Kind::Wal => ShardWal::open(dir, Arc::new(RealFs)).map(|_| ()),
    }));
    match outcome {
        Ok(Ok(())) => Ok("Ok"),
        Ok(Err(e)) => Ok(variant_name(&e)),
        Err(payload) => {
            let msg = payload
                .downcast_ref::<&str>()
                .map(|s| s.to_string())
                .or_else(|| payload.downcast_ref::<String>().cloned())
                .unwrap_or_else(|| "non-string panic payload".to_string());
            Err(format!("loader panicked: {msg}"))
        }
    }
}

fn main() {
    let mut cases = 2000usize;
    let mut seed = 0x50AFu64;
    let mut verbose = false;
    let mut args = std::env::args().skip(1);
    while let Some(arg) = args.next() {
        match arg.as_str() {
            "--cases" => {
                cases = args
                    .next()
                    .and_then(|v| v.parse().ok())
                    .unwrap_or_else(|| usage("--cases needs a number"))
            }
            "--seed" => {
                seed = args
                    .next()
                    .and_then(|v| v.parse().ok())
                    .unwrap_or_else(|| usage("--seed needs a number"))
            }
            "--verbose" => verbose = true,
            other => usage(&format!("unknown argument {other}")),
        }
    }

    let root = TempDir::new().expect("tempdir");
    let corpora = build_corpora(root.path());
    println!(
        "fuzz_decode: {} corpora ({}), {cases} cases, seed {seed:#x}, alloc cap {} MiB",
        corpora.len(),
        corpora.iter().map(|c| c.name).collect::<Vec<_>>().join(", "),
        ALLOC_CAP >> 20
    );

    let case_root = root.path().join("case");
    let mut tallies: std::collections::BTreeMap<(&str, &str), u64> = Default::default();
    let mut findings: Vec<String> = Vec::new();
    for case in 0..cases {
        let corpus = &corpora[case % corpora.len()];
        let case_seed = seed ^ (case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut rng = Rng::new(case_seed);

        // Fresh case dir: loaders may repair/quarantine files in place.
        let _ = std::fs::remove_dir_all(&case_root);
        std::fs::create_dir_all(&case_root).expect("case dir");
        for (name, bytes) in &corpus.siblings {
            std::fs::write(case_root.join(name), bytes).expect("write sibling");
        }
        let mut mutated = corpus.pristine.clone();
        mutate(&mut mutated, &mut rng);
        let target = case_root.join(corpus.target);
        std::fs::write(&target, &mutated).expect("write target");

        if verbose {
            println!(
                "case {case}: corpus={} seed={case_seed:#x} len {} -> {}",
                corpus.name,
                corpus.pristine.len(),
                mutated.len()
            );
        }
        match run_loader(corpus.kind, &case_root, &target) {
            Ok(label) => *tallies.entry((corpus.name, label)).or_insert(0) += 1,
            Err(finding) => {
                let repro = format!(
                    "corpus={} case={case} case_seed={case_seed:#x} (rerun: fuzz_decode --cases \
                     {cases} --seed {seed} --verbose): {finding}",
                    corpus.name
                );
                eprintln!("FINDING: {repro}");
                findings.push(repro);
            }
        }
        if (case + 1) % 2000 == 0 {
            println!("  ... {} / {cases} cases", case + 1);
        }
    }

    println!("outcome distribution:");
    for ((corpus, label), n) in &tallies {
        println!("  {corpus:<22} {label:<10} {n}");
    }
    if !findings.is_empty() {
        eprintln!("fuzz_decode FAILED: {} finding(s)", findings.len());
        std::process::exit(1);
    }
    println!("fuzz_decode passed: {cases} mutated loads, zero panics, zero invariant breaks");
}

fn usage(msg: &str) -> ! {
    eprintln!("fuzz_decode: {msg}\nusage: fuzz_decode [--cases N] [--seed S] [--verbose]");
    std::process::exit(2);
}
