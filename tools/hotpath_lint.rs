//! `hotpath_lint` — static check for the zero-alloc hot-path contract.
//!
//! The query hot paths are bracketed with marker comments:
//!
//! ```text
//! // hot-path: no-alloc begin
//! ...scan / rerank / merge...
//! // hot-path: no-alloc end
//! ```
//!
//! This tool scans `rust/src` for those regions and fails when a line
//! inside one contains an allocating construct (`vec![`,
//! `Vec::with_capacity`, `.to_vec()`, `Box::new(`, `format!(`,
//! `.collect()`, `.to_string()`, `String::from(`). The allocation test
//! (`rust/tests/alloc.rs`) proves the steady state is clean at runtime;
//! this lint catches the regression at review time, before anyone has to
//! bisect a p99 blip, and covers paths the test fixtures do not reach.
//!
//! The check is textual on purpose: it runs in the CI lint job with no
//! compilation, and the marked regions are short enough that the crude
//! line-level match has no false positives (comments are stripped before
//! matching). It also fails when no region is found at all — if the
//! markers are renamed, the lint must be updated, not silently disarmed.
//!
//! Usage: hotpath_lint [src-root (default rust/src)]

use std::path::{Path, PathBuf};

/// Substrings that allocate. Line-level, matched after stripping `//`
/// comments.
const BANNED: &[&str] = &[
    "vec![",
    "Vec::with_capacity",
    ".to_vec()",
    "Box::new(",
    "format!(",
    ".collect()",
    ".collect::<",
    ".to_string()",
    "String::from(",
    "String::new(",
];

const BEGIN: &str = "hot-path: no-alloc begin";
const END: &str = "hot-path: no-alloc end";

fn rust_files(dir: &Path, out: &mut Vec<PathBuf>) -> std::io::Result<()> {
    for entry in std::fs::read_dir(dir)? {
        let path = entry?.path();
        if path.is_dir() {
            rust_files(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

/// Code portion of a line: everything before a `//` comment.
fn code_part(line: &str) -> &str {
    match line.find("//") {
        Some(i) => &line[..i],
        None => line,
    }
}

fn main() {
    let root = std::env::args()
        .nth(1)
        .unwrap_or_else(|| "rust/src".to_string());
    let mut files = Vec::new();
    if let Err(e) = rust_files(Path::new(&root), &mut files) {
        eprintln!("hotpath_lint: cannot walk {root}: {e}");
        std::process::exit(2);
    }
    files.sort();

    let mut regions = 0usize;
    let mut violations: Vec<String> = Vec::new();
    for file in &files {
        let text = match std::fs::read_to_string(file) {
            Ok(t) => t,
            Err(e) => {
                eprintln!("hotpath_lint: cannot read {}: {e}", file.display());
                std::process::exit(2);
            }
        };
        let mut open_at: Option<usize> = None;
        for (i, line) in text.lines().enumerate() {
            let lineno = i + 1;
            if line.contains(BEGIN) {
                if open_at.is_some() {
                    violations.push(format!(
                        "{}:{lineno}: nested `{BEGIN}` marker",
                        file.display()
                    ));
                }
                open_at = Some(lineno);
                regions += 1;
                continue;
            }
            if line.contains(END) {
                if open_at.is_none() {
                    violations.push(format!(
                        "{}:{lineno}: `{END}` without matching begin",
                        file.display()
                    ));
                }
                open_at = None;
                continue;
            }
            if open_at.is_some() {
                let code = code_part(line);
                for pat in BANNED {
                    if code.contains(pat) {
                        violations.push(format!(
                            "{}:{lineno}: `{pat}` inside a no-alloc hot-path region \
                             (opened at line {})",
                            file.display(),
                            open_at.unwrap()
                        ));
                    }
                }
            }
        }
        if let Some(open) = open_at {
            violations.push(format!(
                "{}:{open}: `{BEGIN}` region never closed",
                file.display()
            ));
        }
    }

    if regions == 0 {
        eprintln!(
            "hotpath_lint FAILED: no `{BEGIN}` regions found under {root} — \
             markers renamed or removed? The lint must not be silently disarmed."
        );
        std::process::exit(1);
    }
    if !violations.is_empty() {
        eprintln!(
            "hotpath_lint FAILED: {} violation(s) in {} region(s):",
            violations.len(),
            regions
        );
        for v in &violations {
            eprintln!("  {v}");
        }
        std::process::exit(1);
    }
    println!(
        "hotpath_lint passed: {regions} no-alloc region(s) across {} files, no allocating \
         constructs",
        files.len()
    );
}
