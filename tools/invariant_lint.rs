//! `invariant_lint` — textual invariant checks for the crate's unsafe
//! code, panic discipline, and concurrency facade. Successor to the
//! original `hotpath_lint` (whose no-alloc rule is carried over as rule
//! D). Runs in the CI lint job with no compilation; every rule is a
//! line-level scan over `rust/src`.
//!
//! Rules:
//!
//! * **A. `[unsafe-safety-comment]`** — every `unsafe` block, `unsafe
//!   fn`, and `unsafe impl` must be immediately preceded by a
//!   `// SAFETY:` comment (or a `/// # Safety` doc section), scanning
//!   upward past comments, attributes, and adjacent `unsafe impl` lines.
//!   Bare `unsafe fn(...)` function-pointer *types* are exempt.
//! * **B. `[serve-no-panic]`** — no `.unwrap()` / `.expect(` inside a
//!   `serve-path: no-panic` or `hot-path: no-alloc` region. These are the
//!   per-query code paths; a poisoned lock or stray `None` must degrade,
//!   not abort the process. Suppress a deliberate use with
//!   `// lint: allow(panic)` on the same line. (`.unwrap_or*` fallbacks
//!   do not match and stay allowed.)
//! * **C. `[std-sync-facade]`** — no direct use of `std::sync` lock,
//!   condvar, or atomic types outside `util/sync.rs` / `util/loom.rs`;
//!   everything else must go through the `crate::util::sync` facade so
//!   the loom models exercise the same primitives production runs.
//!   `Arc`, `Weak`, `mpsc`, `Ordering`, and the poison/result types are
//!   allowed (they need no modeling). Suppress with
//!   `// lint: allow(std-sync)` on the same line.
//! * **D. `[hotpath-no-alloc]`** — no allocating construct inside a
//!   `hot-path: no-alloc` region (the original hotpath_lint rule; the
//!   runtime counterpart is `rust/tests/alloc.rs`).
//! * **E. `[marker-coverage]`** — the batched-execution kernels must
//!   keep their marker regions: every file in `REQUIRED_HOT_COVERAGE`
//!   needs at least one `hot-path: no-alloc` region (grouped scans,
//!   replay, shard merge, GEMM tile loops) and every file in
//!   `REQUIRED_SERVE_COVERAGE` at least one `serve-path: no-panic`
//!   region (LUT16 scan kernels, top-k admission). Deleting a marker
//!   from a kernel must break CI, not quietly shrink rule B/D coverage.
//!
//! The lint fails when zero regions of either marker kind are found —
//! renaming the markers must break CI, not silently disarm the rules.
//!
//! Usage: `invariant_lint [src-root]` (default `rust/src`), or
//! `invariant_lint --self-test` to verify each rule still fires on a
//! seeded violation and stays quiet on conforming code.

use std::path::{Path, PathBuf};

/// Substrings that allocate (rule D). Matched after stripping `//`
/// comments.
const BANNED_ALLOC: &[&str] = &[
    "vec![",
    "Vec::with_capacity",
    ".to_vec()",
    "Box::new(",
    "format!(",
    ".collect()",
    ".collect::<",
    ".to_string()",
    "String::from(",
    "String::new(",
];

/// Panic-capable calls banned inside serve/hot regions (rule B). Exact
/// substrings: `.unwrap_or(`/`.unwrap_or_else(`/`.unwrap_or_default(` do
/// not match.
const BANNED_PANIC: &[&str] = &[".unwrap()", ".expect("];

/// `std::sync` identifiers that must come from the facade (rule C).
/// Anything starting with `Atomic` is banned as well.
const BANNED_SYNC: &[&str] = &[
    "Mutex",
    "RwLock",
    "Condvar",
    "OnceLock",
    "Once",
    "Barrier",
    "LazyLock",
    "WaitTimeoutResult",
    "MutexGuard",
    "RwLockReadGuard",
    "RwLockWriteGuard",
];

const HOT_BEGIN: &str = "hot-path: no-alloc begin";
const HOT_END: &str = "hot-path: no-alloc end";
const SERVE_BEGIN: &str = "serve-path: no-panic begin";
const SERVE_END: &str = "serve-path: no-panic end";

/// Files exempt from rule C: the facade itself and the model checker
/// backing it.
const SYNC_EXEMPT: &[&str] = &["util/sync.rs", "util/loom.rs"];

/// How far rule A scans upward (in lines) looking for a SAFETY comment.
const SAFETY_SCAN_CAP: usize = 12;

/// Rule E: files that must each carry at least one `hot-path: no-alloc`
/// region — the zero-alloc kernels of the batched query path (grouped
/// segment-major scans + per-query replay, the collection fan-out and
/// batch merge, and the blocked GEMM feeding partition selection).
const REQUIRED_HOT_COVERAGE: &[&str] = &[
    "index/searcher.rs",
    "index/collection.rs",
    "linalg/matrix.rs",
];

/// Rule E: files that must each carry at least one `serve-path:
/// no-panic` region — the per-candidate scan and admission kernels.
const REQUIRED_SERVE_COVERAGE: &[&str] = &["quant/lut16.rs", "linalg/topk.rs"];

#[derive(Default)]
struct Report {
    violations: Vec<String>,
    hot_regions: usize,
    serve_regions: usize,
    /// Files (normalized paths) containing ≥1 region of each kind.
    hot_files: Vec<String>,
    serve_files: Vec<String>,
    files: usize,
}

/// Rule E: which required suffixes have no region in `covered`?
fn missing_coverage<'a>(required: &[&'a str], covered: &[String]) -> Vec<&'a str> {
    required
        .iter()
        .filter(|suffix| !covered.iter().any(|f| f.ends_with(*suffix)))
        .copied()
        .collect()
}

fn rust_files(dir: &Path, out: &mut Vec<PathBuf>) -> std::io::Result<()> {
    for entry in std::fs::read_dir(dir)? {
        let path = entry?.path();
        if path.is_dir() {
            rust_files(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

/// Code portion of a line: everything before a `//` comment.
fn code_part(line: &str) -> &str {
    match line.find("//") {
        Some(i) => &line[..i],
        None => line,
    }
}

fn is_ident_char(c: char) -> bool {
    c.is_ascii_alphanumeric() || c == '_'
}

/// Rule A: does `code` contain an `unsafe` construct that needs a SAFETY
/// comment? (Excludes `unsafe fn(` function-pointer types.)
fn needs_safety_comment(code: &str) -> bool {
    for (i, _) in code.match_indices("unsafe") {
        let before_ok = i == 0
            || !code[..i]
                .chars()
                .next_back()
                .is_some_and(is_ident_char);
        let after = &code[i + "unsafe".len()..];
        let after_ok = !after.chars().next().is_some_and(is_ident_char);
        if !(before_ok && after_ok) {
            continue; // part of a longer identifier
        }
        if after.trim_start().starts_with("fn(") {
            continue; // `unsafe fn(..)` function-pointer type, not a definition
        }
        return true;
    }
    false
}

/// Rule A: scan upward from line `i` (0-based) for a SAFETY comment,
/// skipping comments, attributes, and adjacent `unsafe impl` lines.
fn has_safety_comment(lines: &[&str], i: usize) -> bool {
    // Trailing comment on the line itself also counts.
    if lines[i].contains("SAFETY:") {
        return true;
    }
    let mut scanned = 0;
    let mut k = i;
    while k > 0 && scanned < SAFETY_SCAN_CAP {
        k -= 1;
        scanned += 1;
        let t = lines[k].trim_start();
        if t.contains("SAFETY:") || t.contains("# Safety") {
            return true;
        }
        let skippable = t.starts_with("//")
            || t.starts_with("#[")
            || t.starts_with("#!")
            || t.contains("unsafe impl");
        if !skippable {
            return false;
        }
    }
    false
}

/// Rule C: collect the identifiers a `std::sync::` reference names. For
/// `use` lines that's every identifier up to the `;` (covers brace
/// lists); elsewhere it's the `ident(::ident)*` chain only, so unrelated
/// identifiers later on the line can't false-positive.
fn sync_idents<'a>(code: &'a str, is_use: bool, out: &mut Vec<&'a str>) {
    for (i, _) in code.match_indices("std::sync::") {
        let rest = &code[i + "std::sync::".len()..];
        if is_use {
            let upto = rest.find(';').map_or(rest, |j| &rest[..j]);
            out.extend(upto.split(|c| !is_ident_char(c)).filter(|s| !s.is_empty()));
        } else {
            let mut rest = rest;
            loop {
                let end = rest.find(|c| !is_ident_char(c)).unwrap_or(rest.len());
                if end > 0 {
                    out.push(&rest[..end]);
                }
                match rest[end..].strip_prefix("::") {
                    Some(next) if next.chars().next().is_some_and(is_ident_char) => rest = next,
                    _ => break,
                }
            }
        }
    }
}

fn lint_file(path: &Path, text: &str, report: &mut Report) {
    let display = path.display();
    let rel = path.to_string_lossy().replace('\\', "/");
    let sync_exempt = SYNC_EXEMPT.iter().any(|suffix| rel.ends_with(suffix));
    let lines: Vec<&str> = text.lines().collect();

    // (kind, open-line) of the current marker region, if any.
    let mut hot_open: Option<usize> = None;
    let mut serve_open: Option<usize> = None;

    for (i, line) in lines.iter().enumerate() {
        let lineno = i + 1;
        let code = code_part(line);

        // Region bookkeeping (markers live in comments, so match the raw
        // line).
        if line.contains(HOT_BEGIN) {
            if hot_open.is_some() {
                report
                    .violations
                    .push(format!("[hotpath-no-alloc] {display}:{lineno}: nested `{HOT_BEGIN}`"));
            }
            hot_open = Some(lineno);
            report.hot_regions += 1;
            if !report.hot_files.contains(&rel) {
                report.hot_files.push(rel.clone());
            }
            continue;
        }
        if line.contains(HOT_END) {
            if hot_open.is_none() {
                report.violations.push(format!(
                    "[hotpath-no-alloc] {display}:{lineno}: `{HOT_END}` without matching begin"
                ));
            }
            hot_open = None;
            continue;
        }
        if line.contains(SERVE_BEGIN) {
            if serve_open.is_some() {
                report.violations.push(format!(
                    "[serve-no-panic] {display}:{lineno}: nested `{SERVE_BEGIN}`"
                ));
            }
            serve_open = Some(lineno);
            report.serve_regions += 1;
            if !report.serve_files.contains(&rel) {
                report.serve_files.push(rel.clone());
            }
            continue;
        }
        if line.contains(SERVE_END) {
            if serve_open.is_none() {
                report.violations.push(format!(
                    "[serve-no-panic] {display}:{lineno}: `{SERVE_END}` without matching begin"
                ));
            }
            serve_open = None;
            continue;
        }

        // Rule A: unsafe needs a SAFETY comment.
        if needs_safety_comment(code) && !has_safety_comment(&lines, i) {
            report.violations.push(format!(
                "[unsafe-safety-comment] {display}:{lineno}: `unsafe` without a preceding \
                 `// SAFETY:` comment"
            ));
        }

        // Rule B: no panic-capable calls in serve/hot regions.
        if (serve_open.is_some() || hot_open.is_some()) && !line.contains("lint: allow(panic)") {
            for pat in BANNED_PANIC {
                if code.contains(pat) {
                    let opened = serve_open.or(hot_open).unwrap_or(lineno);
                    report.violations.push(format!(
                        "[serve-no-panic] {display}:{lineno}: `{pat}` inside a no-panic region \
                         (opened at line {opened}); degrade instead, or annotate \
                         `// lint: allow(panic)`"
                    ));
                }
            }
        }

        // Rule C: std::sync primitives must come through the facade.
        if !sync_exempt && code.contains("std::sync::") && !line.contains("lint: allow(std-sync)")
        {
            let is_use = code.trim_start().starts_with("use ")
                || code.trim_start().starts_with("pub use ");
            let mut idents = Vec::new();
            sync_idents(code, is_use, &mut idents);
            for ident in idents {
                if BANNED_SYNC.contains(&ident) || ident.starts_with("Atomic") {
                    report.violations.push(format!(
                        "[std-sync-facade] {display}:{lineno}: `std::sync::{ident}` bypasses \
                         `crate::util::sync` (loom models can't see it); import from the facade, \
                         or annotate `// lint: allow(std-sync)`"
                    ));
                }
            }
        }

        // Rule D: no allocation in hot-path regions.
        if let Some(opened) = hot_open {
            for pat in BANNED_ALLOC {
                if code.contains(pat) {
                    report.violations.push(format!(
                        "[hotpath-no-alloc] {display}:{lineno}: `{pat}` inside a no-alloc \
                         hot-path region (opened at line {opened})"
                    ));
                }
            }
        }
    }
    if let Some(open) = hot_open {
        report
            .violations
            .push(format!("[hotpath-no-alloc] {display}:{open}: `{HOT_BEGIN}` never closed"));
    }
    if let Some(open) = serve_open {
        report
            .violations
            .push(format!("[serve-no-panic] {display}:{open}: `{SERVE_BEGIN}` never closed"));
    }
}

fn lint_root(root: &Path) -> std::io::Result<Report> {
    let mut files = Vec::new();
    rust_files(root, &mut files)?;
    files.sort();
    let mut report = Report {
        files: files.len(),
        ..Report::default()
    };
    for file in &files {
        let text = std::fs::read_to_string(file)?;
        lint_file(file, &text, &mut report);
    }
    Ok(report)
}

/// Seed one violation per rule in a scratch tree and check each fires;
/// then check a conforming tree stays quiet. Guards the lint itself
/// against rot.
fn self_test() -> Result<(), String> {
    let dir = std::env::temp_dir().join(format!("soar_invariant_lint_{}", std::process::id()));
    let src = dir.join("util");
    std::fs::create_dir_all(&src).map_err(|e| format!("mkdir {}: {e}", src.display()))?;

    // One conforming file exercising every rule's happy path; also
    // provides the ≥1-region-of-each-kind floor.
    let clean = concat!(
        "pub fn serve(x: Option<u32>) -> u32 {\n",
        "    // serve-path: no-panic begin\n",
        "    let v = x.unwrap_or(0);\n",
        "    // hot-path: no-alloc begin\n",
        "    let w = v + 1;\n",
        "    // hot-path: no-alloc end\n",
        "    // serve-path: no-panic end\n",
        "    w\n",
        "}\n",
        "use crate::util::sync::Mutex;\n",
        "// SAFETY: null is a valid (unused) pointer value.\n",
        "pub fn probe() { unsafe { std::ptr::read_volatile(&0u8); } }\n",
    );
    let seeded: &[(&str, &str, &str)] = &[
        (
            "bad_unsafe.rs",
            "[unsafe-safety-comment]",
            "pub fn f() { unsafe { std::ptr::read_volatile(&0u8); } }\n",
        ),
        (
            "bad_panic.rs",
            "[serve-no-panic]",
            concat!(
                "pub fn f(x: Option<u32>) -> u32 {\n",
                "    // serve-path: no-panic begin\n",
                "    let v = x.unwrap();\n",
                "    // serve-path: no-panic end\n",
                "    v\n",
                "}\n",
            ),
        ),
        (
            "bad_sync.rs",
            "[std-sync-facade]",
            "use std::sync::Mutex;\n",
        ),
        (
            "bad_alloc.rs",
            "[hotpath-no-alloc]",
            concat!(
                "pub fn f() -> Vec<u32> {\n",
                "    // hot-path: no-alloc begin\n",
                "    let v = vec![1, 2, 3];\n",
                "    // hot-path: no-alloc end\n",
                "    v\n",
                "}\n",
            ),
        ),
    ];

    let run = |report: std::io::Result<Report>| -> Result<Report, String> {
        report.map_err(|e| format!("self-test lint run failed: {e}"))
    };
    let result = (|| {
        std::fs::write(src.join("clean.rs"), clean)
            .map_err(|e| format!("write clean.rs: {e}"))?;
        // Conforming tree first: must be quiet.
        let report = run(lint_root(&dir))?;
        if !report.violations.is_empty() {
            return Err(format!(
                "conforming tree reported violations: {:?}",
                report.violations
            ));
        }
        if report.hot_regions == 0 || report.serve_regions == 0 {
            return Err("conforming tree did not count its regions".to_string());
        }
        // Rule E plumbing: the scratch tree has none of the required
        // kernel files, so every required suffix must be reported
        // missing; a tree that does cover them must report none.
        if missing_coverage(REQUIRED_HOT_COVERAGE, &report.hot_files).len()
            != REQUIRED_HOT_COVERAGE.len()
            || missing_coverage(REQUIRED_SERVE_COVERAGE, &report.serve_files).len()
                != REQUIRED_SERVE_COVERAGE.len()
        {
            return Err("marker-coverage: scratch tree spuriously satisfied coverage".to_string());
        }
        let covered: Vec<String> = REQUIRED_HOT_COVERAGE
            .iter()
            .map(|s| format!("rust/src/{s}"))
            .collect();
        if !missing_coverage(REQUIRED_HOT_COVERAGE, &covered).is_empty() {
            return Err("marker-coverage: suffix match failed on covered paths".to_string());
        }
        // Now seed one violation per rule and require each tag to fire.
        for (name, _, contents) in seeded {
            std::fs::write(src.join(name), contents)
                .map_err(|e| format!("write {name}: {e}"))?;
        }
        let report = run(lint_root(&dir))?;
        for (name, tag, _) in seeded {
            let hit = report
                .violations
                .iter()
                .any(|v| v.starts_with(tag) && v.contains(name));
            if !hit {
                return Err(format!(
                    "seeded violation in {name} not detected (wanted {tag}); got {:?}",
                    report.violations
                ));
            }
        }
        Ok(())
    })();
    let _ = std::fs::remove_dir_all(&dir);
    result
}

fn main() {
    let arg = std::env::args().nth(1);
    if arg.as_deref() == Some("--self-test") {
        match self_test() {
            Ok(()) => {
                println!(
                    "invariant_lint self-test passed: all rules fire on seeded violations \
                     and the marker-coverage matcher behaves"
                );
                return;
            }
            Err(e) => {
                eprintln!("invariant_lint self-test FAILED: {e}");
                std::process::exit(1);
            }
        }
    }

    let root = arg.unwrap_or_else(|| "rust/src".to_string());
    let report = match lint_root(Path::new(&root)) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("invariant_lint: cannot scan {root}: {e}");
            std::process::exit(2);
        }
    };
    if report.hot_regions == 0 || report.serve_regions == 0 {
        eprintln!(
            "invariant_lint FAILED: found {} `{HOT_BEGIN}` and {} `{SERVE_BEGIN}` regions under \
             {root} — markers renamed or removed? The lint must not be silently disarmed.",
            report.hot_regions, report.serve_regions
        );
        std::process::exit(1);
    }
    // Rule E: the batched-execution kernels must keep their regions.
    let hot_missing = missing_coverage(REQUIRED_HOT_COVERAGE, &report.hot_files);
    let serve_missing = missing_coverage(REQUIRED_SERVE_COVERAGE, &report.serve_files);
    if !hot_missing.is_empty() || !serve_missing.is_empty() {
        eprintln!(
            "invariant_lint FAILED [marker-coverage]: kernel files lost their marker \
             regions — no-alloc missing in {hot_missing:?}, no-panic missing in \
             {serve_missing:?}. Restore the markers (or update the required-coverage \
             lists deliberately)."
        );
        std::process::exit(1);
    }
    if !report.violations.is_empty() {
        eprintln!("invariant_lint FAILED: {} violation(s):", report.violations.len());
        for v in &report.violations {
            eprintln!("  {v}");
        }
        std::process::exit(1);
    }
    println!(
        "invariant_lint passed: {} files, {} no-alloc region(s) in {} file(s), \
         {} no-panic region(s) in {} file(s), all unsafe blocks documented, facade clean",
        report.files,
        report.hot_regions,
        report.hot_files.len(),
        report.serve_regions,
        report.serve_files.len()
    );
}
