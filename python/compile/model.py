"""L2: build-time JAX compute graphs for the SOAR engine.

Each entry point here is a pure JAX function that calls the L1 Pallas
kernels (``kernels/``) and is AOT-lowered to HLO text by ``aot.py``. The
Rust runtime (``rust/src/runtime``) loads the resulting artifacts and runs
them via PJRT on the query/build hot paths; Python never runs at serve time.

Entry points
------------
* ``centroid_topk``      — query-time: score a query batch against the
  codebook (Pallas matmul) and return the top-t partitions per query
  (scores + int32 indices). Fusing top-k into the same HLO module keeps the
  PJRT→Rust transfer at O(B·t) instead of O(B·c).
* ``centroid_score``     — same, without top-k (full score matrix). Used by
  the KMR/statistics evaluators which need every partition's rank.
* ``soar_assign_scores`` — build-time: the fused Theorem 3.1 loss matrix
  for a datapoint batch. λ is a traced scalar, so a single artifact serves
  every λ (Fig 9's sweep reuses one executable).

Shape buckets
-------------
PJRT executables are shape-specialized, so ``aot.py`` exports each entry
point at a small set of (B, c, d[, t]) *buckets*; the Rust caller zero-pads
its actual shapes up to the nearest bucket and ignores padded rows/columns
(padding d is exact: zero dims add zero to every inner product and norm;
padded centroid columns are filtered out Rust-side).
"""

import jax
import jax.numpy as jnp

from compile.kernels.centroid_score import centroid_score as _centroid_score_kernel
from compile.kernels.pq_lut import pq_lut as _pq_lut_kernel
from compile.kernels.soar_assign import soar_assign as _soar_assign_kernel


def centroid_score(q, c):
    """Full MIPS score matrix ``[B, c]`` via the Pallas scoring kernel."""
    return (_centroid_score_kernel(q, c),)


def make_centroid_topk(t):
    """Returns the top-t entry point specialized for a static ``t``.

    Implemented as a full descending sort + slice rather than
    ``jax.lax.top_k``: the latter lowers to the ``topk`` HLO instruction
    (with the ``largest`` attribute), which the xla_extension 0.5.1 HLO
    text parser used by the Rust runtime rejects. ``sort_key_val`` lowers
    to a plain ``sort``, which round-trips fine; the extra O(c log c) vs
    O(c log t) cost is negligible at our codebook sizes.
    """

    def centroid_topk(q, c):
        scores = _centroid_score_kernel(q, c)
        idx = jnp.broadcast_to(
            jnp.arange(scores.shape[1], dtype=jnp.int32)[None, :], scores.shape
        )
        neg_sorted, idx_sorted = jax.lax.sort_key_val(-scores, idx, dimension=1)
        return (-neg_sorted[:, :t], idx_sorted[:, :t])

    return centroid_topk


def soar_assign_scores(x, r_hat, c, lam):
    """Fused SOAR loss matrix ``[B, c]``; λ traced (shape ``[1]``)."""
    return (_soar_assign_kernel(x, r_hat, c, lam[0]),)


def pq_lut_batch(q, codebooks):
    """Per-query PQ lookup tables ``[B, m, 16]`` (ADC stage input)."""
    return (_pq_lut_kernel(q, codebooks),)


# ---------------------------------------------------------------------------
# Export specs consumed by aot.py. Keep this list small: each entry is one
# PJRT compile at Rust start-up. Buckets cover the scales exercised by the
# examples, benches, and experiment drivers (see DESIGN.md §4).
# ---------------------------------------------------------------------------

F32 = jnp.float32


def _s(*shape):
    return jax.ShapeDtypeStruct(shape, F32)


def export_specs():
    """List of (name, fn, example_args, meta) to AOT-compile."""
    specs = []
    for (b, c, d, t) in [
        (64, 1024, 128, 256),
        (64, 4096, 128, 512),
    ]:
        specs.append((
            f"centroid_topk_b{b}_c{c}_d{d}_t{t}",
            make_centroid_topk(t),
            (_s(b, d), _s(c, d)),
            {"kind": "centroid_topk", "b": b, "c": c, "d": d, "t": t},
        ))
    for (b, c, d) in [
        (64, 1024, 128),
        (64, 4096, 128),
    ]:
        specs.append((
            f"centroid_score_b{b}_c{c}_d{d}",
            centroid_score,
            (_s(b, d), _s(c, d)),
            {"kind": "centroid_score", "b": b, "c": c, "d": d},
        ))
    for (b, c, d) in [
        (256, 1024, 128),
        (256, 4096, 128),
    ]:
        specs.append((
            f"soar_assign_b{b}_c{c}_d{d}",
            soar_assign_scores,
            (_s(b, d), _s(b, d), _s(c, d), _s(1)),
            {"kind": "soar_assign", "b": b, "c": c, "d": d},
        ))
    # PQ LUT construction (m = d/s subspaces, s = 2, 16 centers).
    for (b, m, sdim) in [
        (64, 64, 2),
    ]:
        specs.append((
            f"pq_lut_b{b}_m{m}_s{sdim}",
            pq_lut_batch,
            (_s(b, m * sdim), _s(m, 16, sdim)),
            {"kind": "pq_lut", "b": b, "c": m, "d": m * sdim, "t": 0},
        ))
    return specs
