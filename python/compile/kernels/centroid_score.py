"""L1 Pallas kernel: batched query→centroid MIPS scoring (Q @ Cᵀ).

This is the top of the SOAR query hot path: each incoming query batch is
scored against every VQ partition center, and the top-t partitions are then
searched. On the paper's CPU testbed this is ScaNN's AVX-512 cache-blocked
matmul; on TPU we re-express it for the MXU:

* the grid tiles the output ``[B, c]`` into ``(block_b, block_c)`` MXU-sized
  blocks;
* each grid step streams one ``[block_c, d]`` tile of the codebook from HBM
  into VMEM (``BlockSpec`` below expresses that HBM↔VMEM schedule — the
  analog of the CPU implementation's L2-cache blocking);
* the contraction runs over the full ``d`` (≤ 512 in all our shape buckets,
  so a query tile + codebook tile + output tile fit comfortably in VMEM;
  see DESIGN.md §8 for the footprint arithmetic).

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, so the kernel is lowered through the Pallas interpreter into
plain HLO. Correctness vs :func:`ref.centroid_score_ref` is enforced by
pytest; TPU performance is estimated analytically in DESIGN.md.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default MXU-friendly block shape. f32 VMEM footprint per grid step at
# d=512: (128 + 256) * 512 * 4B + 128*256*4B ≈ 0.9 MB — leaves plenty of
# VMEM for double-buffering the streamed codebook tiles.
DEFAULT_BLOCK_B = 128
DEFAULT_BLOCK_C = 256


def _score_kernel(q_ref, c_ref, o_ref):
    """One (block_b, block_c) output tile: o = q @ cᵀ.

    ``preferred_element_type=float32`` keeps the MXU accumulation in f32
    even if inputs are later switched to bf16.
    """
    o_ref[...] = jax.lax.dot_general(
        q_ref[...],
        c_ref[...],
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


@functools.partial(jax.jit, static_argnames=("block_b", "block_c"))
def centroid_score(q, c, *, block_b=DEFAULT_BLOCK_B, block_c=DEFAULT_BLOCK_C):
    """Scores ``[B, c] = q @ cᵀ`` via the tiled Pallas kernel.

    Shapes must tile exactly: ``B % block_b == 0`` and ``c % block_c == 0``
    (the AOT shape buckets guarantee this; the Rust caller zero-pads).
    """
    bsz, d = q.shape
    csz, d2 = c.shape
    assert d == d2, f"dim mismatch: {d} vs {d2}"
    bb = min(block_b, bsz)
    bc = min(block_c, csz)
    assert bsz % bb == 0 and csz % bc == 0, (
        f"shapes ({bsz},{csz}) must tile by ({bb},{bc})"
    )
    grid = (bsz // bb, csz // bc)
    return pl.pallas_call(
        _score_kernel,
        grid=grid,
        in_specs=[
            # Query tile: varies along grid axis 0 only.
            pl.BlockSpec((bb, d), lambda i, j: (i, 0)),
            # Codebook tile streamed from HBM: varies along grid axis 1.
            pl.BlockSpec((bc, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bb, bc), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((bsz, csz), jnp.float32),
        interpret=True,
    )(q, c)
