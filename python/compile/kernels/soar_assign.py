"""L1 Pallas kernel: fused SOAR spilled-assignment loss (Theorem 3.1).

For every (datapoint, centroid) pair the index builder needs

    L(x, c) = ‖x − c‖² + λ⟨r̂, x − c⟩²
            = ‖x‖² − 2⟨x,c⟩ + ‖c‖² + λ(⟨r̂,x⟩ − ⟨r̂,c⟩)²

where r̂ is the unit-normalized primary residual of x. Expanding the loss
this way turns the whole computation into *two* matmuls against the codebook
tile (X·Cᵀ and R̂·Cᵀ) plus cheap rank-1 corrections — all fused into a single
pass over each codebook tile while it is resident in VMEM. The naive form
(materialize x−c for every pair) would be O(B·c·d) memory traffic; the fused
form is the same two-matmul traffic as plain Euclidean assignment, which is
how SOAR keeps indexing cost close to a standard VQ index (§3.5).

λ enters as a (1,1) SMEM-style operand so one compiled artifact serves every
λ (the λ-sweep of Fig 9 reuses a single executable).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_B = 128
DEFAULT_BLOCK_C = 256


def _soar_kernel(lam_ref, x_ref, rhat_ref, c_ref, o_ref):
    """One (block_b, block_c) loss tile, fully fused."""
    x = x_ref[...]            # [bb, d]
    rhat = rhat_ref[...]      # [bb, d]
    c = c_ref[...]            # [bc, d]
    lam = lam_ref[0, 0]

    dot = lambda a, b: jax.lax.dot_general(
        a, b, dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    xc = dot(x, c)                                  # [bb, bc] ⟨x,c⟩
    rc = dot(rhat, c)                               # [bb, bc] ⟨r̂,c⟩
    x_sq = jnp.sum(x * x, axis=1, keepdims=True)    # [bb, 1]
    rx = jnp.sum(rhat * x, axis=1, keepdims=True)   # [bb, 1]
    c_sq = jnp.sum(c * c, axis=1)[None, :]          # [1, bc]

    par = rx - rc
    o_ref[...] = x_sq - 2.0 * xc + c_sq + lam * par * par


@functools.partial(jax.jit, static_argnames=("block_b", "block_c"))
def soar_assign(x, r_hat, c, lam,
                *, block_b=DEFAULT_BLOCK_B, block_c=DEFAULT_BLOCK_C):
    """Fused SOAR loss ``[B, c]`` for datapoints ``x`` vs codebook ``c``.

    Args:
      x:     ``[B, d]`` datapoints.
      r_hat: ``[B, d]`` unit-normalized primary residuals (zero rows OK —
             the loss then reduces to plain squared Euclidean distance).
      c:     ``[c, d]`` codebook.
      lam:   scalar λ (traced; one artifact serves all λ values).
    """
    bsz, d = x.shape
    csz, d2 = c.shape
    assert d == d2 and x.shape == r_hat.shape
    bb = min(block_b, bsz)
    bc = min(block_c, csz)
    assert bsz % bb == 0 and csz % bc == 0, (
        f"shapes ({bsz},{csz}) must tile by ({bb},{bc})"
    )
    lam_arr = jnp.asarray(lam, jnp.float32).reshape(1, 1)
    grid = (bsz // bb, csz // bc)
    return pl.pallas_call(
        _soar_kernel,
        grid=grid,
        in_specs=[
            # λ broadcast to every grid step.
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
            pl.BlockSpec((bb, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bb, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bc, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bb, bc), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((bsz, csz), jnp.float32),
        interpret=True,
    )(lam_arr, x, r_hat, c)
