"""L1 Pallas kernel: batched PQ lookup-table construction.

The ADC scan scores candidates as sums of per-subspace table lookups; the
table for a query q is ``lut[j, c] = ⟨q[j·s:(j+1)·s], codebook[j, c]⟩``
over m subspaces × 16 centers. For a query *batch* this is a block-diagonal
batched matmul — ``einsum('bjs,jcs->bjc')`` — which maps cleanly onto the
MXU when expressed per-subspace-block.

The kernel tiles over the query batch; each grid step holds the full
codebook tensor (m × 16 × s ≤ 64·16·2 f32 = 8 KB — VMEM-trivial) and one
query tile, emitting the [bb, m, 16] LUT slab.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from compile.kernels.ref import PQ_CENTERS

DEFAULT_BLOCK_B = 128


def _lut_kernel(q_ref, cb_ref, o_ref):
    """One query tile: lut[b, j, c] = Σ_s q[b, j, s]·cb[j, c, s]."""
    bb = q_ref.shape[0]
    m, centers, s = cb_ref.shape
    q = q_ref[...].reshape(bb, m, s)
    cb = cb_ref[...]
    o_ref[...] = jax.lax.dot_general(
        q,
        cb,
        # contract over s; batch over the subspace dim j
        dimension_numbers=(((2,), (2,)), ((1,), (0,))),
        preferred_element_type=jnp.float32,
    ).transpose(1, 0, 2)  # batched dot yields [j, b, c] → [b, j, c]


@functools.partial(jax.jit, static_argnames=("block_b",))
def pq_lut(q, codebooks, *, block_b=DEFAULT_BLOCK_B):
    """LUT slab ``[B, m, 16]`` for a query batch.

    Args:
      q: ``[B, m*s]`` queries (dims grouped by subspace; ragged tails are
         the caller's responsibility — pad to a multiple of s).
      codebooks: ``[m, 16, s]`` per-subspace PQ centers.
    """
    bsz, d = q.shape
    m, centers, s = codebooks.shape
    assert centers == PQ_CENTERS, f"expected {PQ_CENTERS} centers, got {centers}"
    assert d == m * s, f"query dim {d} != m*s = {m * s}"
    bb = min(block_b, bsz)
    assert bsz % bb == 0, f"batch {bsz} must tile by {bb}"
    return pl.pallas_call(
        _lut_kernel,
        grid=(bsz // bb,),
        in_specs=[
            pl.BlockSpec((bb, d), lambda i: (i, 0)),
            pl.BlockSpec((m, centers, s), lambda i: (0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((bb, m, centers), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, m, centers), jnp.float32),
        interpret=True,
    )(q, codebooks)
