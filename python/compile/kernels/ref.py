"""Pure-jnp correctness oracles for the Pallas kernels.

These are the ground-truth implementations of the two dense hot-spots of a
SOAR index:

* ``centroid_score_ref``   — batched query→centroid MIPS scoring Q @ Cᵀ.
* ``soar_assign_ref``      — the Theorem 3.1 SOAR assignment loss
                             ‖x−c‖² + λ‖proj_r (x−c)‖² for every centroid.

The Pallas kernels in :mod:`centroid_score` and :mod:`soar_assign` must match
these to float tolerance; pytest (``python/tests``) enforces that with
hypothesis sweeps over shapes and dtypes.
"""

import jax.numpy as jnp

#: Centers per PQ subspace (4-bit codes; §3.5).
PQ_CENTERS = 16


def centroid_score_ref(q, c):
    """MIPS scores of each query against each centroid.

    Args:
      q: ``[B, d]`` query batch.
      c: ``[c, d]`` codebook.

    Returns:
      ``[B, c]`` inner-product scores.
    """
    return q @ c.T


def soar_assign_ref(x, r_hat, c, lam):
    """SOAR spilled-assignment loss for each (datapoint, centroid) pair.

    Implements Theorem 3.1 of the paper:

        L(r', r) ∝ ‖r'‖² + λ‖proj_r r'‖²,   r' = x − c.

    ``r_hat`` is the *unit-normalized* primary residual r/‖r‖; rows whose
    primary residual was exactly zero should be passed as zero vectors, which
    gracefully degrades the loss to plain squared Euclidean distance.

    Args:
      x:     ``[B, d]`` datapoints to spill.
      r_hat: ``[B, d]`` unit-normalized primary residuals.
      c:     ``[c, d]`` codebook.
      lam:   scalar λ ≥ 0 (python float or 0-d array).

    Returns:
      ``[B, c]`` loss values; argmin along axis 1 (excluding the primary
      partition) is the SOAR spilled assignment.
    """
    # ‖x−c‖² expanded: ‖x‖² − 2⟨x,c⟩ + ‖c‖²
    x_sq = jnp.sum(x * x, axis=1, keepdims=True)          # [B,1]
    c_sq = jnp.sum(c * c, axis=1)[None, :]                # [1,c]
    xc = x @ c.T                                          # [B,c]
    l2 = x_sq - 2.0 * xc + c_sq
    # ‖proj_r r'‖² = ⟨r̂, x−c⟩² = (⟨r̂,x⟩ − ⟨r̂,c⟩)²
    rx = jnp.sum(r_hat * x, axis=1, keepdims=True)        # [B,1]
    rc = r_hat @ c.T                                      # [B,c]
    par = rx - rc
    return l2 + lam * par * par


def pq_lut_ref(q, codebooks):
    """Oracle for the PQ LUT kernel: lut[b, j, c] = ⟨q_sub, center⟩.

    Args:
      q:         ``[B, m*s]`` queries.
      codebooks: ``[m, 16, s]`` per-subspace centers.

    Returns:
      ``[B, m, 16]`` inner-product lookup tables.
    """
    bsz, d = q.shape
    m, centers, s = codebooks.shape
    qr = q.reshape(bsz, m, s)
    return jnp.einsum("bjs,jcs->bjc", qr, codebooks)
