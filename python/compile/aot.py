"""AOT pipeline: lower every L2 entry point to HLO *text* + a manifest.

HLO text — NOT ``lowered.compile()`` output or a serialized HloModuleProto —
is the interchange format. jax ≥ 0.5 emits protos with 64-bit instruction
ids which the ``xla`` crate's bundled xla_extension 0.5.1 rejects
(``proto.id() <= INT_MAX``); the HLO text parser on the Rust side reassigns
ids and round-trips cleanly. See /opt/xla-example/README.md.

Outputs (under ``artifacts/``):
  * ``<name>.hlo.txt``   — one HLO module per entry point / shape bucket.
  * ``manifest.json``    — machine-readable index the Rust runtime uses to
    pick the right artifact for a given request shape.

Run via ``make artifacts`` (no-op when inputs are unchanged).
"""

import argparse
import hashlib
import json
import os
import sys

import jax
from jax._src.lib import xla_client as xc

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from compile import model  # noqa: E402


def to_hlo_text(fn, example_args):
    """jit → lower → stablehlo → XlaComputation → HLO text."""
    lowered = jax.jit(fn).lower(*example_args)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def main():
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out-dir", default="../artifacts",
                        help="artifact output directory")
    args = parser.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = {"format": "hlo-text", "version": 1, "entries": []}
    for name, fn, example_args, meta in model.export_specs():
        text = to_hlo_text(fn, example_args)
        fname = f"{name}.hlo.txt"
        path = os.path.join(args.out_dir, fname)
        with open(path, "w") as f:
            f.write(text)
        entry = dict(meta)
        entry.update({
            "name": name,
            "file": fname,
            "sha256": hashlib.sha256(text.encode()).hexdigest(),
            "inputs": [
                {"shape": list(a.shape), "dtype": str(a.dtype)}
                for a in example_args
            ],
        })
        manifest["entries"].append(entry)
        print(f"wrote {path} ({len(text)} chars)")

    mpath = os.path.join(args.out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {mpath} ({len(manifest['entries'])} entries)")


if __name__ == "__main__":
    main()
