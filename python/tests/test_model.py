"""L2 shape/semantics tests: model entry points + AOT export specs."""

import json
import os

import numpy as np
import jax.numpy as jnp
import pytest

from compile import model
from compile.kernels.ref import centroid_score_ref, soar_assign_ref


def test_centroid_score_entry_shape():
    rng = np.random.default_rng(0)
    q = rng.normal(size=(64, 128)).astype(np.float32)
    c = rng.normal(size=(1024, 128)).astype(np.float32)
    (out,) = model.centroid_score(q, c)
    assert out.shape == (64, 1024)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(centroid_score_ref(q, c)),
                               rtol=2e-4, atol=2e-2)


@pytest.mark.parametrize("t", [1, 16, 256])
def test_centroid_topk_matches_numpy(t):
    rng = np.random.default_rng(1)
    q = rng.normal(size=(64, 128)).astype(np.float32)
    c = rng.normal(size=(1024, 128)).astype(np.float32)
    vals, idx = model.make_centroid_topk(t)(q, c)
    assert vals.shape == (64, t) and idx.shape == (64, t)
    assert idx.dtype == jnp.int32
    scores = q @ c.T
    want_idx = np.argsort(-scores, axis=1, kind="stable")[:, :t]
    want_vals = np.take_along_axis(scores, want_idx, axis=1)
    np.testing.assert_allclose(np.asarray(vals), want_vals,
                               rtol=2e-4, atol=2e-2)
    # indices may differ on exact ties; values are the real contract, but
    # with continuous random data ties are measure-zero:
    assert (np.asarray(idx) == want_idx).mean() > 0.999


def test_topk_values_sorted_descending():
    rng = np.random.default_rng(2)
    q = rng.normal(size=(64, 128)).astype(np.float32)
    c = rng.normal(size=(1024, 128)).astype(np.float32)
    vals, _ = model.make_centroid_topk(64)(q, c)
    v = np.asarray(vals)
    assert (np.diff(v, axis=1) <= 1e-6).all()


def test_soar_assign_scores_entry():
    rng = np.random.default_rng(3)
    x = rng.normal(size=(256, 128)).astype(np.float32)
    r = rng.normal(size=(256, 128)).astype(np.float32)
    r /= np.linalg.norm(r, axis=1, keepdims=True)
    c = rng.normal(size=(1024, 128)).astype(np.float32)
    lam = np.array([1.5], np.float32)
    (out,) = model.soar_assign_scores(x, r, c, lam)
    assert out.shape == (256, 1024)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(soar_assign_ref(x, r, c, 1.5)),
                               rtol=2e-4, atol=5e-2)


def test_export_specs_consistent():
    """Every export spec: callable runs at its example shapes, names unique."""
    specs = model.export_specs()
    assert len(specs) >= 4
    names = [s[0] for s in specs]
    assert len(set(names)) == len(names)
    for name, fn, example_args, meta in specs:
        assert meta["kind"] in ("centroid_topk", "centroid_score",
                                "soar_assign", "pq_lut")
        args = [np.zeros(a.shape, np.float32) for a in example_args]
        outs = fn(*args)
        assert isinstance(outs, tuple) and len(outs) >= 1
        if meta["kind"] == "centroid_topk":
            assert outs[0].shape == (meta["b"], meta["t"])
            assert outs[1].shape == (meta["b"], meta["t"])
        elif meta["kind"] == "pq_lut":
            assert outs[0].shape == (meta["b"], meta["c"], 16)
        else:
            assert outs[0].shape == (meta["b"], meta["c"])


ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


@pytest.mark.skipif(not os.path.exists(os.path.join(ARTIFACTS, "manifest.json")),
                    reason="artifacts not built (run `make artifacts`)")
def test_manifest_matches_specs():
    """manifest.json must describe exactly the current export specs."""
    with open(os.path.join(ARTIFACTS, "manifest.json")) as f:
        manifest = json.load(f)
    assert manifest["format"] == "hlo-text"
    by_name = {e["name"]: e for e in manifest["entries"]}
    for name, _fn, example_args, meta in model.export_specs():
        assert name in by_name, f"stale artifacts: {name} missing; re-run make artifacts"
        entry = by_name[name]
        assert entry["kind"] == meta["kind"]
        got_shapes = [tuple(i["shape"]) for i in entry["inputs"]]
        want_shapes = [tuple(a.shape) for a in example_args]
        assert got_shapes == want_shapes
        path = os.path.join(ARTIFACTS, entry["file"])
        assert os.path.exists(path)
        text = open(path).read()
        assert "HloModule" in text
