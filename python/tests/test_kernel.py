"""L1 correctness: Pallas kernels vs the pure-jnp oracle.

This is the CORE correctness signal for the compute layer. Hypothesis sweeps
shapes (including non-default block tilings), value scales, and λ; every
case must match ``ref.py`` to float32 tolerance.
"""

import numpy as np
import pytest
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels.centroid_score import centroid_score
from compile.kernels.pq_lut import pq_lut
from compile.kernels.soar_assign import soar_assign
from compile.kernels.ref import centroid_score_ref, pq_lut_ref, soar_assign_ref

# Shared tolerances: interpret-mode Pallas reduces in a different order than
# XLA's fused matmul, so allow a few ULPs scaled by the contraction length.
RTOL, ATOL = 2e-4, 2e-4


def _rand(rng, *shape, scale=1.0):
    return (rng.normal(size=shape) * scale).astype(np.float32)


def _unit_rows(a):
    n = np.linalg.norm(a, axis=1, keepdims=True)
    n[n == 0] = 1.0
    return (a / n).astype(np.float32)


# ---------------------------------------------------------------------------
# centroid_score
# ---------------------------------------------------------------------------

shape_strategy = st.tuples(
    st.sampled_from([1, 2, 4, 8, 16, 64, 128]),        # B
    st.sampled_from([4, 16, 64, 256, 512, 1024]),      # c
    st.sampled_from([1, 2, 3, 8, 32, 64, 128]),        # d
)


@settings(max_examples=25, deadline=None)
@given(shape=shape_strategy, seed=st.integers(0, 2**31 - 1),
       scale=st.sampled_from([1e-3, 1.0, 1e3]))
def test_centroid_score_matches_ref(shape, seed, scale):
    b, c, d = shape
    rng = np.random.default_rng(seed)
    q = _rand(rng, b, d, scale=scale)
    cb = _rand(rng, c, d, scale=scale)
    got = np.asarray(centroid_score(q, cb))
    want = np.asarray(centroid_score_ref(q, cb))
    np.testing.assert_allclose(
        got, want, rtol=RTOL, atol=ATOL * scale * scale * max(d, 1))


@pytest.mark.parametrize("block_b,block_c", [(1, 1), (2, 4), (8, 16),
                                             (64, 64), (128, 256)])
def test_centroid_score_block_shapes(block_b, block_c):
    """Tiling must not change the numbers (block sweep used by perf pass)."""
    rng = np.random.default_rng(7)
    q = _rand(rng, 128, 64)
    cb = _rand(rng, 256, 64)
    got = np.asarray(centroid_score(q, cb, block_b=block_b, block_c=block_c))
    want = np.asarray(centroid_score_ref(q, cb))
    np.testing.assert_allclose(got, want, rtol=RTOL, atol=1e-2)


def test_centroid_score_rejects_ragged():
    rng = np.random.default_rng(0)
    # 192 does not tile by the default 128-row block.
    with pytest.raises(AssertionError):
        centroid_score(_rand(rng, 192, 8), _rand(rng, 128, 8))


def test_centroid_score_identity_rows():
    """Orthonormal queries against themselves → identity score matrix."""
    eye = np.eye(16, dtype=np.float32)
    got = np.asarray(centroid_score(eye, eye))
    np.testing.assert_allclose(got, eye, rtol=0, atol=1e-6)


# ---------------------------------------------------------------------------
# soar_assign
# ---------------------------------------------------------------------------

soar_shape_strategy = st.tuples(
    st.sampled_from([1, 2, 8, 32, 128]),               # B
    st.sampled_from([4, 16, 64, 256, 1024]),           # c
    st.sampled_from([2, 3, 8, 32, 128]),               # d
)


@settings(max_examples=25, deadline=None)
@given(shape=soar_shape_strategy, seed=st.integers(0, 2**31 - 1),
       lam=st.sampled_from([0.0, 0.5, 1.0, 1.5, 4.0, 100.0]))
def test_soar_assign_matches_ref(shape, seed, lam):
    b, c, d = shape
    rng = np.random.default_rng(seed)
    x = _rand(rng, b, d)
    rhat = _unit_rows(_rand(rng, b, d))
    cb = _rand(rng, c, d)
    got = np.asarray(soar_assign(x, rhat, cb, lam))
    want = np.asarray(soar_assign_ref(x, rhat, cb, lam))
    np.testing.assert_allclose(got, want, rtol=RTOL,
                               atol=ATOL * max(1.0, lam) * max(d, 1))


def test_soar_lambda_zero_is_euclidean():
    """Corollary 3.1.1: λ=0 ⇒ loss is plain squared Euclidean distance."""
    rng = np.random.default_rng(3)
    x = _rand(rng, 8, 16)
    rhat = _unit_rows(_rand(rng, 8, 16))
    cb = _rand(rng, 32, 16)
    got = np.asarray(soar_assign(x, rhat, cb, 0.0))
    want = ((x[:, None, :] - cb[None, :, :]) ** 2).sum(-1)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-3)


def test_soar_orthogonal_residual_no_penalty():
    """Corollary 3.1.2: r ⊥ r' ⇒ loss equals ‖r'‖² regardless of λ."""
    d = 8
    x = np.zeros((1, d), np.float32)
    x[0, 0] = 2.0                      # x on axis 0
    rhat = np.zeros((1, d), np.float32)
    rhat[0, 1] = 1.0                   # primary residual on axis 1
    cb = np.zeros((4, d), np.float32)  # candidate residuals x−c stay on axis 0
    cb[1, 0] = 1.0
    cb[2, 0] = -1.0
    cb[3, 0] = 3.0
    for lam in (0.0, 1.0, 10.0):
        got = np.asarray(soar_assign(x, rhat, cb, lam))[0]
        want = ((x[0, 0] - cb[:, 0]) ** 2)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_soar_parallel_residual_full_penalty():
    """Collinear case of Fig 3: r ∥ r' ⇒ loss = (1+λ)‖r'‖²."""
    d = 4
    x = np.zeros((1, d), np.float32)
    x[0, 0] = 2.0
    rhat = np.zeros((1, d), np.float32)
    rhat[0, 0] = 1.0                   # residual parallel to x−c below
    cb = np.zeros((2, d), np.float32)  # c at origin ⇒ r' = x, parallel to r̂
    for lam in (0.0, 1.0, 2.5):
        got = np.asarray(soar_assign(x, rhat, cb, lam))[0, 0]
        want = (1.0 + lam) * 4.0
        np.testing.assert_allclose(got, want, rtol=1e-5)


def test_soar_monotone_in_lambda():
    """Loss is non-decreasing in λ for every pair (penalty term ≥ 0)."""
    rng = np.random.default_rng(11)
    x = _rand(rng, 16, 32)
    rhat = _unit_rows(_rand(rng, 16, 32))
    cb = _rand(rng, 64, 32)
    prev = np.asarray(soar_assign(x, rhat, cb, 0.0))
    for lam in (0.5, 1.0, 2.0, 8.0):
        cur = np.asarray(soar_assign(x, rhat, cb, lam))
        assert (cur >= prev - 1e-4).all()
        prev = cur


def test_soar_zero_rhat_degrades_to_euclidean():
    """Zero primary residual rows must not produce NaNs."""
    rng = np.random.default_rng(5)
    x = _rand(rng, 4, 8)
    rhat = np.zeros((4, 8), np.float32)
    cb = _rand(rng, 16, 8)
    got = np.asarray(soar_assign(x, rhat, cb, 2.0))
    want = ((x[:, None, :] - cb[None, :, :]) ** 2).sum(-1)
    assert np.isfinite(got).all()
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-3)


# ---------------------------------------------------------------------------
# pq_lut
# ---------------------------------------------------------------------------

lut_shape_strategy = st.tuples(
    st.sampled_from([1, 2, 8, 64, 128]),    # B
    st.sampled_from([1, 2, 8, 32, 64]),     # m subspaces
    st.sampled_from([1, 2, 4]),             # s dims per subspace
)


@settings(max_examples=20, deadline=None)
@given(shape=lut_shape_strategy, seed=st.integers(0, 2**31 - 1))
def test_pq_lut_matches_ref(shape, seed):
    b, m, s = shape
    rng = np.random.default_rng(seed)
    q = _rand(rng, b, m * s)
    cb = _rand(rng, m, 16, s)
    got = np.asarray(pq_lut(q, cb))
    want = np.asarray(pq_lut_ref(q, cb))
    np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL * s)


def test_pq_lut_block_identity():
    """Each LUT row must equal the scalar per-subspace inner products."""
    rng = np.random.default_rng(1)
    q = _rand(rng, 4, 8)      # m=4, s=2
    cb = _rand(rng, 4, 16, 2)
    got = np.asarray(pq_lut(q, cb))
    for b in range(4):
        for j in range(4):
            for c in range(16):
                want = q[b, 2 * j: 2 * j + 2] @ cb[j, c]
                assert abs(got[b, j, c] - want) < 1e-4


def test_pq_lut_rejects_bad_shapes():
    rng = np.random.default_rng(2)
    with pytest.raises(AssertionError):
        pq_lut(_rand(rng, 2, 9), _rand(rng, 4, 16, 2))   # 9 != 4*2
    with pytest.raises(AssertionError):
        pq_lut(_rand(rng, 2, 8), _rand(rng, 4, 8, 2))    # 8 centers
