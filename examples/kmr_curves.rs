//! Fig 6 / Table 2 reproduction: KMR curves for the three index types
//! (no spilling, naive spilling, SOAR).
//!
//! Run with: `cargo run --release --example kmr_curves [-- --n 50000]`

use soar_ann::eval::experiments::{kmr_experiment, ExpConfig};
use soar_ann::runtime::{default_artifact_dir, Engine};
use soar_ann::util::cli::Args;

fn main() -> soar_ann::Result<()> {
    let args = Args::parse(std::env::args().skip(1), &["n", "dim", "queries", "k", "lambda", "quick"])?;
    let mut cfg = if args.get_bool("quick") { ExpConfig::quick() } else { ExpConfig::default() };
    cfg.n = args.get_usize("n", cfg.n)?;
    cfg.dim = args.get_usize("dim", cfg.dim)?;
    cfg.num_queries = args.get_usize("queries", cfg.num_queries)?;
    cfg.k = args.get_usize("k", cfg.k)?;
    cfg.lambda = args.get_f32("lambda", cfg.lambda)?;
    let engine = Engine::auto(&default_artifact_dir());
    println!("engine backend: {}", engine.backend_name());
    kmr_experiment(&cfg, &engine)
}
