//! §3 / §5.2 analysis reproduction: Figs 1, 2, 4, 7, 8 — the residual
//! angle statistics motivating the SOAR loss.
//!
//! Run with: `cargo run --release --example correlation_analysis`

use soar_ann::eval::experiments::{fig1, fig2, fig4, fig7, fig8, ExpConfig};
use soar_ann::runtime::{default_artifact_dir, Engine};
use soar_ann::util::cli::Args;

fn main() -> soar_ann::Result<()> {
    let args = Args::parse(std::env::args().skip(1), &["n", "dim", "queries", "lambda", "quick"])?;
    let mut cfg = if args.get_bool("quick") { ExpConfig::quick() } else { ExpConfig::default() };
    cfg.n = args.get_usize("n", cfg.n)?;
    cfg.lambda = args.get_f32("lambda", cfg.lambda)?;
    let engine = Engine::auto(&default_artifact_dir());
    fig1(&cfg, &engine)?;
    fig2(&cfg, &engine)?;
    fig4(&cfg, &engine)?;
    fig7(&cfg, &engine)?;
    fig8(&cfg, &engine)
}
