//! Fig 10 reproduction: SOAR's advantage grows with dataset size and
//! recall target (fixed 400 points/partition across sizes).
//!
//! Run with: `cargo run --release --example scaling_law [-- --quick]`

use soar_ann::eval::experiments::{fig10, ExpConfig};
use soar_ann::runtime::{default_artifact_dir, Engine};
use soar_ann::util::cli::Args;

fn main() -> soar_ann::Result<()> {
    let args = Args::parse(std::env::args().skip(1), &["dim", "queries", "quick"])?;
    let mut cfg = if args.get_bool("quick") { ExpConfig::quick() } else { ExpConfig::default() };
    cfg.dim = args.get_usize("dim", cfg.dim)?;
    let engine = Engine::auto(&default_artifact_dir());
    fig10(&cfg, &engine)
}
