//! Quickstart: build a SOAR index over a synthetic corpus and search it.
//!
//! Run with: `cargo run --release --example quickstart`

use soar_ann::config::{IndexConfig, SearchParams, SpillMode};
use soar_ann::data::ground_truth::ground_truth_mips;
use soar_ann::data::synthetic::SyntheticConfig;
use soar_ann::index::{build_index, SearchScratch, Searcher};
use soar_ann::runtime::{default_artifact_dir, Engine};

fn main() -> soar_ann::Result<()> {
    // 1. A 10k-point Glove-like synthetic corpus with 100 queries.
    let ds = SyntheticConfig::glove_like(10_000, 64, 100, 42).generate();
    println!("dataset: {} ({} x {})", ds.name, ds.n(), ds.dim());

    // 2. The engine: PJRT artifacts when built (make artifacts), else the
    //    identical CPU fallback.
    let engine = Engine::auto(&default_artifact_dir());
    println!("engine backend: {}", engine.backend_name());

    // 3. Build a SOAR index (~400 points/partition, λ = 1).
    let cfg = IndexConfig::for_dataset(ds.n(), SpillMode::Soar { lambda: 1.0 });
    let index = build_index(&engine, &ds.data, &cfg)?;
    println!(
        "index: {} partitions, {} posting entries",
        index.num_partitions(),
        index.total_postings()
    );

    // 4. Search.
    let params = SearchParams { k: 10, top_t: 6, rerank_budget: 200 };
    let searcher = Searcher::new(&index, &engine);
    let mut scratch = SearchScratch::new(&index);
    let (hits, stats) = searcher.search(ds.queries.row(0), &params, &mut scratch);
    println!("query 0 neighbors:");
    for h in &hits {
        println!("  id {:>6}  score {:.4}", h.id, h.score);
    }
    println!(
        "scanned {} of {} postings across {} partitions ({} spilled duplicates skipped)",
        stats.points_scanned,
        index.total_postings(),
        stats.partitions_probed,
        stats.duplicates_skipped
    );

    // 5. Verify against exact ground truth.
    let gt = ground_truth_mips(&ds.data, &ds.queries, 10);
    let mut results = Vec::new();
    for qi in 0..ds.num_queries() {
        let (res, _) = searcher.search(ds.queries.row(qi), &params, &mut scratch);
        results.push(res.into_iter().map(|s| s.id).collect::<Vec<_>>());
    }
    println!("recall@10 over {} queries: {:.3}", ds.num_queries(), gt.mean_recall(&results));
    Ok(())
}
