//! Fig 9 reproduction: the λ tradeoff — VQ distortion E‖r'‖² rises with λ
//! while the quantized-score-error correlation ρ falls.
//!
//! Run with: `cargo run --release --example lambda_sweep`

use soar_ann::eval::experiments::{fig9, ExpConfig};
use soar_ann::runtime::{default_artifact_dir, Engine};
use soar_ann::util::cli::Args;

fn main() -> soar_ann::Result<()> {
    let args = Args::parse(std::env::args().skip(1), &["n", "dim", "quick"])?;
    let mut cfg = if args.get_bool("quick") { ExpConfig::quick() } else { ExpConfig::default() };
    cfg.n = args.get_usize("n", cfg.n)?;
    let engine = Engine::auto(&default_artifact_dir());
    fig9(&cfg, &engine)
}
