//! END-TO-END VALIDATION DRIVER (DESIGN.md §5).
//!
//! Builds SOAR and baseline indices over a real (synthetic-Glove) workload,
//! starts the full serving stack — router → dynamic batcher → worker pool,
//! with centroid scoring running through the PJRT artifacts when built —
//! drives it with closed-loop concurrent clients, and reports recall@10,
//! throughput, and latency percentiles for each index type. This proves
//! all three layers compose: Pallas kernel (L1) → AOT HLO (L2) → Rust
//! coordinator (L3).
//!
//! Run with: `cargo run --release --example serve_benchmark [-- --n 100000]`

use std::sync::Arc;

use soar_ann::config::{IndexConfig, SearchParams, ServeConfig, SpillMode};
use soar_ann::coordinator::server::{closed_loop_load, ServeEngine};
use soar_ann::data::ground_truth::ground_truth_mips;
use soar_ann::data::synthetic::SyntheticConfig;
use soar_ann::eval::plot::render_table;
use soar_ann::index::build_index;
use soar_ann::runtime::{default_artifact_dir, Engine};
use soar_ann::util::cli::Args;

fn main() -> soar_ann::Result<()> {
    let args = Args::parse(
        std::env::args().skip(1),
        &["n", "dim", "queries", "clients", "requests", "top-t", "rerank", "quick"],
    )?;
    let quick = args.get_bool("quick");
    let n = args.get_usize("n", if quick { 10_000 } else { 100_000 })?;
    let dim = args.get_usize("dim", 64)?;
    let nq = args.get_usize("queries", 256)?;
    let clients = args.get_usize("clients", 8)?;
    let requests = args.get_usize("requests", if quick { 32 } else { 128 })?;
    let top_t = args.get_usize("top-t", 8)?;
    let rerank = args.get_usize("rerank", 200)?;

    println!("== SOAR end-to-end serving benchmark ==");
    let ds = SyntheticConfig::glove_like(n, dim, nq, 42).generate();
    println!("corpus: {} ({} x {}), {} queries", ds.name, n, dim, nq);
    let engine = Arc::new(Engine::auto(&default_artifact_dir()));
    println!("engine backend: {}", engine.backend_name());
    let gt = ground_truth_mips(&ds.data, &ds.queries, 10);

    let mut rows = Vec::new();
    for (name, spill) in [
        ("no-spill VQ", SpillMode::None),
        ("spill, no SOAR", SpillMode::Nearest),
        ("SOAR λ=1", SpillMode::Soar { lambda: 1.0 }),
    ] {
        let cfg = IndexConfig::for_dataset(n, spill);
        let t0 = std::time::Instant::now();
        let index = Arc::new(build_index(&engine, &ds.data, &cfg)?);
        let build_s = t0.elapsed().as_secs_f64();

        // Offline recall measurement at the serving operating point.
        let params = SearchParams { k: 10, top_t, rerank_budget: rerank };
        let searcher = soar_ann::index::Searcher::new(&index, &engine);
        let results = searcher.search_batch(&ds.queries, &params)?;
        let ids: Vec<Vec<u32>> = results
            .iter()
            .map(|(r, _)| r.iter().map(|s| s.id).collect())
            .collect();
        let recall = gt.mean_recall(&ids);
        let mean_scanned: f64 = results
            .iter()
            .map(|(_, s)| s.points_scanned as f64)
            .sum::<f64>()
            / results.len() as f64;

        // Live serving run.
        let server = ServeEngine::start(
            index.clone(),
            engine.clone(),
            params,
            ServeConfig {
                max_batch: 64,
                max_wait_us: 200,
                workers: 4,
                queue_depth: 4096,
            },
        );
        let handle = server.handle();
        let elapsed = closed_loop_load(&handle, &ds.queries, clients, requests);
        let snap = server.metrics().snapshot();
        server.shutdown();

        rows.push(vec![
            name.to_string(),
            format!("{build_s:.1}s"),
            format!("{recall:.3}"),
            format!("{:.0}", mean_scanned),
            format!("{:.0}", snap.queries as f64 / elapsed),
            format!("{}", snap.p50_us),
            format!("{}", snap.p99_us),
            format!("{:.1}", snap.mean_batch),
        ]);
    }
    println!(
        "{}",
        render_table(
            &[
                "index",
                "build",
                "recall@10",
                "pts scanned",
                "QPS",
                "p50 µs",
                "p99 µs",
                "batch"
            ],
            &rows
        )
    );
    println!("(same top_t/rerank operating point for all indices; SOAR should match or");
    println!(" beat baselines on recall at equal scan budgets — Fig 6/11 shape)");
    Ok(())
}
